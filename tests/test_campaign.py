"""Campaign driver (repro.core.campaign): chunk-boundary bitwise
determinism on all three kernels, kill-and-resume parity, metrics-tap
neutrality, pad-waste accounting, bounded host memory, and the
sketch-only-payload guards in grid/hist/benchmarks.run.

The determinism tests are the contract the module docstring states:
the campaign accumulator is a sequential left fold over points in
global index order, so its bytes cannot depend on where the chunk
boundaries fall — chunked and one-dispatch runs must produce EQUAL
fingerprints, not merely close aggregates.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.core.analytic import LinearServiceModel
from repro.core.campaign import campaign, plan_chunks
from repro.core.engine import queue_capacity
from repro.core.grid import FleetGrid, GenGrid, SweepGrid
from repro.core.hist import hist_percentiles

V100 = LinearServiceModel(alpha=0.1438, tau0=1.8874)

N_BATCHES = 12


def _loss_grid(n=48):
    """A structured grid exercising every loss axis (finite waiting
    rooms, deadlines, retry orbits) plus both service families, so the
    fold's has_loss branch and goodput arithmetic are all under test."""
    i = np.arange(n)
    b = np.where(i % 2 == 0, 4, 16).astype(np.int32)
    fr = np.linspace(0.3, 0.9, n, dtype=np.float32)
    lam = fr * b / (V100.alpha * b + V100.tau0)
    return SweepGrid.from_points(
        lam, V100.alpha, V100.tau0, b_max=b,
        dist=np.where(i % 2 == 0, 0, 1).astype(np.int32),
        q_max=np.where(i % 3 == 0, 0, 16).astype(np.int32),
        deadline=np.where(i % 4 == 0, 50.0, 0.0).astype(np.float32),
        retry_rate=np.where(i % 5 == 0, 0.25, 0.0).astype(np.float32))


@pytest.fixture(scope="module")
def sweep_pair():
    g = _loss_grid(48)
    chunked = campaign(g, chunk_size=16, n_batches=N_BATCHES, seed=3)
    whole = campaign(g, chunk_size=48, n_batches=N_BATCHES, seed=3)
    return chunked, whole


class TestChunkDeterminism:
    def test_sweep_chunked_equals_whole(self, sweep_pair):
        chunked, whole = sweep_pair
        assert chunked.n_chunks == 3 and whole.n_chunks == 1
        assert chunked.fingerprint() == whole.fingerprint()
        assert chunked.totals == whole.totals
        assert chunked.totals["jobs"] > 0
        # the loss axes actually fired (otherwise the has_loss branch
        # of the fold went untested)
        assert chunked.totals["overflow_dropped"] > 0
        assert chunked.totals["buffer_dropped"] == 0

    def test_top_k_and_percentiles_chunk_invariant(self, sweep_pair):
        chunked, whole = sweep_pair
        assert chunked.top_latency == whole.top_latency
        assert chunked.top_goodput == whole.top_goodput
        p = chunked.percentiles((50, 95, 99))
        assert p == whole.percentiles((50, 95, 99))
        assert np.all(np.isfinite(p)) and p[0] <= p[1] <= p[2]

    def test_fleet_chunked_equals_whole(self):
        k = np.tile([1, 2, 4], 8).astype(np.int32)
        lam = np.linspace(0.5, 2.0, 24, dtype=np.float32) * k
        g = FleetGrid.from_points(lam, V100.alpha, V100.tau0, k=k,
                                  routing="jsq", b_max=8,
                                  q_max=np.where(np.arange(24) % 2 == 0,
                                                 0, 12).astype(np.int32))
        a = campaign(g, chunk_size=8, n_steps=48, seed=7)
        b = campaign(g, chunk_size=24, n_steps=48, seed=7)
        assert a.kind == "fleet" and a.n_chunks == 3
        assert a.fingerprint() == b.fingerprint()

    def test_gen_chunked_equals_whole(self):
        lam = np.linspace(0.05, 0.4, 18, dtype=np.float32)
        g = GenGrid.from_points(
            lam, 0.02, 0.5, 0.01, 2.0, prompt_len=32, gen_tokens=8,
            max_active=16,
            q_max=np.where(np.arange(18) % 3 == 0, 0, 8).astype(np.int32))
        a = campaign(g, chunk_size=6, n_steps=64, seed=9)
        b = campaign(g, chunk_size=18, n_steps=64, seed=9)
        assert a.kind == "gen" and a.n_chunks == 3
        assert a.fingerprint() == b.fingerprint()

    def test_sketch_chunked_equals_whole(self):
        g = _loss_grid(32)
        a = campaign(g, chunk_size=16, sketch=True,
                     n_batches=N_BATCHES, seed=3)
        b = campaign(g, chunk_size=32, sketch=True,
                     n_batches=N_BATCHES, seed=3)
        assert a.fingerprint() == b.fingerprint()
        assert np.isfinite(a.percentiles((95,))[0])


class TestResume:
    def test_kill_and_resume_matches_uninterrupted(self, tmp_path):
        g = _loss_grid(48)
        full = campaign(g, chunk_size=16, n_batches=N_BATCHES, seed=3)
        part = campaign(g, chunk_size=16, n_batches=N_BATCHES, seed=3,
                        out_dir=tmp_path / "c", checkpoint_every=1,
                        stop_after_chunks=2)
        assert not part.completed
        res = campaign(g, chunk_size=16, n_batches=N_BATCHES, seed=3,
                       out_dir=tmp_path / "c", resume=True,
                       checkpoint_every=1)
        assert res.completed
        assert res.fingerprint() == full.fingerprint()
        lines = (tmp_path / "c" / "chunks.jsonl").read_text().splitlines()
        rows = [json.loads(l) for l in lines]
        assert [r["chunk"] for r in rows] == [0, 1, 2]
        assert sum(r["points"] for r in rows) == 48

    def test_resume_rejects_changed_config(self, tmp_path):
        g = _loss_grid(32)
        campaign(g, chunk_size=16, n_batches=N_BATCHES, seed=3,
                 out_dir=tmp_path / "c", stop_after_chunks=1)
        with pytest.raises(ValueError, match="does not match"):
            campaign(g, chunk_size=16, n_batches=N_BATCHES + 1, seed=3,
                     out_dir=tmp_path / "c", resume=True)

    def test_resume_rejects_changed_grid(self, tmp_path):
        campaign(_loss_grid(32), chunk_size=16, n_batches=N_BATCHES,
                 seed=3, out_dir=tmp_path / "c", stop_after_chunks=1)
        g2 = _loss_grid(32)
        g2.lam[0] += 0.125
        with pytest.raises(ValueError, match="does not match"):
            campaign(g2, chunk_size=16, n_batches=N_BATCHES, seed=3,
                     out_dir=tmp_path / "c", resume=True)


class TestMetricsTap:
    def test_tapped_bitwise_equals_untapped(self, tmp_path):
        from repro.core.metrics import MetricsTap
        g = _loss_grid(32)
        plain = campaign(g, chunk_size=16, n_batches=N_BATCHES, seed=3)
        jsonl = tmp_path / "m.jsonl"
        with MetricsTap(jsonl, label="camp") as tap:
            tapped = campaign(g, chunk_size=16, n_batches=N_BATCHES,
                              seed=3, metrics_tap=tap, tap_every=2)
        assert tapped.fingerprint() == plain.fingerprint()
        assert tapped.tapped_chunks == 1          # chunk 0 of {0, 1}
        recs = [json.loads(l) for l in jsonl.read_text().splitlines()]
        kinds = [r["type"] for r in recs]
        # every chunk streams one summary record; only the sampled
        # chunk also streams per-superstep records
        assert kinds.count("chunk") == tapped.n_chunks
        assert kinds.count("superstep") > 0


class TestPadAccounting:
    def test_plan_chunks_prefers_divisors(self):
        assert plan_chunks(96, 40) == (32, 3, 0)
        assert plan_chunks(64, 48) == (32, 2, 0)
        # prime n: no divisor in range — keep the request, report waste
        assert plan_chunks(29, 8) == (8, 4, 3)

    def test_padded_rows_sum_to_plan(self):
        g = _loss_grid(29)
        r = campaign(g, chunk_size=8, n_batches=N_BATCHES, seed=3)
        assert r.padded_points == 3
        assert sum(row["padded"] for row in r.rows) == 3
        assert r.totals["points"] == 29


class TestHostMemory:
    def test_pipelined_peak_is_size_independent(self):
        g_small, g_big = _loss_grid(32), _loss_grid(96)
        a = campaign(g_small, chunk_size=16, n_batches=N_BATCHES, seed=3)
        b = campaign(g_big, chunk_size=16, n_batches=N_BATCHES, seed=3)
        assert b.peak_host_result_bytes <= a.peak_host_result_bytes * 1.5
        s = campaign(g_big, chunk_size=16, n_batches=N_BATCHES, seed=3,
                     mode="serial")
        # serial materializes O(points × bins) per chunk on the host
        assert s.peak_host_result_bytes > 10 * b.peak_host_result_bytes

    def test_serial_runs_lightly_loaded_finite_room(self):
        # regression: per-chunk adaptive caps once sized BELOW q_max on
        # low-load chunks, which the plan layer rejects
        lam = np.full(16, 0.3, dtype=np.float32)
        g = SweepGrid.from_points(lam, V100.alpha, V100.tau0, b_max=4,
                                  q_max=256)
        r = campaign(g, chunk_size=8, n_batches=N_BATCHES, seed=3,
                     mode="serial")
        assert r.totals["points"] == 16


class TestCapSizing:
    def test_queue_capacity_holds_full_waiting_room(self):
        # the room bound may cap the load estimate but never undercut
        # the room itself (sweep_plan rejects q_cap < q_max)
        assert queue_capacity(0.3, V100.alpha, V100.tau0, 4,
                              q_max=256) >= 257

    def test_queue_capacity_room_bound_still_caps(self):
        # a super-critical point with a small waiting room must NOT be
        # sized for its (unbounded) load estimate
        assert queue_capacity(50.0, V100.alpha, V100.tau0, 2,
                              q_max=8) <= 1024


class TestSketchOnlyPayloadGuards:
    def test_result_without_hist_raises_informative(self):
        from repro.core.sweep import sweep
        g = SweepGrid.from_points(np.float32([1.0, 2.0]), V100.alpha,
                                  V100.tau0, b_max=8)
        r = sweep(g, n_batches=4)
        bare = dataclasses.replace(r, hist=None, hist_sums=None)
        with pytest.raises(ValueError, match="sketch-only"):
            bare.hist_bin_edges

    def test_hist_percentiles_accepts_merged_1d(self, sweep_pair):
        chunked, _ = sweep_pair
        one_d = hist_percentiles(chunked.hist, (50.0,))
        two_d = hist_percentiles(chunked.hist[None, :], (50.0,))
        assert one_d[0].shape == (1,)
        assert one_d[0][0] == two_d[0][0]

    def test_row_rates_tolerates_structural_payloads(self):
        from benchmarks.run import _row_rates
        doc = {"rows": [
            {"name": "campaign/chunk_witness",
             "payload": {"fingerprint_chunked": "ab12",
                         "bitwise_equal": True}},
            {"name": "campaign/pipelined_speedup",
             "payload": {"speedup": "n/a"}},
            {"name": "campaign/million_point", "points_per_sec": 582.0,
             "payload": {}},
            "not-a-dict",
        ]}
        rates = _row_rates(doc)
        assert rates == {"campaign/million_point":
                         {"points_per_sec": 582.0}}
