"""Fault-injection harness for the campaign driver.

Every injected fault must leave the campaign in a *stated* state:
either a clean retry heals it bitwise, or the damage is quarantined
and reported — in the chunk row, the manifest, and the result — and
the campaign continues.  Nothing is ever silently dropped, and a
killed-and-resumed campaign is bitwise identical to an uninterrupted
one under the same ``FaultPlan`` (the injection schedule is a pure
function of (seed, kind, chunk, attempt), so a resume replays it).
"""
import json

import numpy as np
import pytest

from repro.core.campaign import (CampaignKilled, FaultPlan, campaign,
                                 verify_resume)
from repro.core.grid import SweepGrid

N_POINTS = 32
KW = dict(chunk_size=8, n_batches=256, fault_backoff_s=0.0)


@pytest.fixture(scope="module")
def grid():
    return SweepGrid.from_points(np.linspace(0.3, 0.9, N_POINTS),
                                 0.05, 1.0, b_max=4)


@pytest.fixture(scope="module")
def clean(grid):
    return campaign(grid, **KW)


class TestFaultPlan:
    def test_roll_is_deterministic_and_seeded(self):
        p = FaultPlan(seed=7, p_dispatch=0.5)
        rolls = [p.roll("dispatch", c, a) for c in range(16)
                 for a in range(2)]
        assert rolls == [p.roll("dispatch", c, a) for c in range(16)
                        for a in range(2)]
        assert any(rolls) and not all(rolls)
        q = FaultPlan(seed=8, p_dispatch=0.5)
        assert rolls != [q.roll("dispatch", c, a) for c in range(16)
                         for a in range(2)]

    def test_max_per_chunk_forces_clean(self):
        p = FaultPlan(seed=0, p_dispatch=1.0, max_per_chunk=2)
        assert p.roll("dispatch", 3, 0) and p.roll("dispatch", 3, 1)
        assert not p.roll("dispatch", 3, 2)

    def test_probability_validation(self):
        with pytest.raises(ValueError):
            FaultPlan(p_nan=1.5)
        with pytest.raises(ValueError):
            FaultPlan().roll("meteor", 0)

    def test_requires_pipelined_mode(self, grid):
        with pytest.raises(ValueError, match="pipelined"):
            campaign(grid, mode="serial", fault_plan=FaultPlan(),
                     chunk_size=8, n_batches=256)


class TestDispatchFaults:
    def test_retry_heals_bitwise(self, grid, clean):
        plan = FaultPlan(seed=3, p_dispatch=0.7, max_per_chunk=2)
        r = campaign(grid, fault_plan=plan, fault_retries=4, **KW)
        assert r.fingerprint() == clean.fingerprint()
        assert r.quarantined_chunks == []
        # the rows record the retries the plan forced
        assert any(row["retries"] > 0 for row in r.rows)

    def test_exhausted_retries_quarantine_never_drop(self, grid,
                                                     tmp_path):
        plan = FaultPlan(seed=3, p_dispatch=1.0, max_per_chunk=8)
        r = campaign(grid, fault_plan=plan, fault_retries=1,
                     out_dir=str(tmp_path), **KW)
        assert r.completed
        # every chunk exhausted its retries: all quarantined, all
        # reported — in the result, the rows, and the manifest
        assert len(r.quarantined_chunks) == r.n_chunks
        assert all(q["reason"] == "dispatch" and "error" in q
                   for q in r.quarantined_chunks)
        assert r.quarantined_points == N_POINTS
        assert r.totals["points"] == 0
        man = json.loads((tmp_path / "manifest.json").read_text())
        assert man["quarantined"] == r.quarantined_chunks
        rows_q = sum(row["quarantined"] for row in r.rows)
        assert rows_q == N_POINTS

    def test_partial_quarantine_keeps_other_chunks(self, grid):
        plan = FaultPlan(seed=5, p_dispatch=0.4, max_per_chunk=8)
        r = campaign(grid, fault_plan=plan, fault_retries=0, **KW)
        lost = sum(q["points"] for q in r.quarantined_chunks)
        assert 0 < lost < N_POINTS
        assert r.totals["points"] == N_POINTS - lost
        assert r.quarantined_points == lost


class TestNaNFaults:
    def test_fold_guard_quarantines_and_continues(self, grid,
                                                  tmp_path):
        plan = FaultPlan(seed=5, p_nan=0.6)
        r = campaign(grid, fault_plan=plan, out_dir=str(tmp_path),
                     **KW)
        assert r.completed
        assert r.quarantined_chunks, "plan never fired — pick a seed"
        assert all(q["reason"] == "nonfinite"
                   for q in r.quarantined_chunks)
        # the poison never reached the accumulator
        for k in ("sum_latency_jobs", "sum_latency", "sum_util",
                  "sum_batch", "hist_sums", "max_ci"):
            assert np.all(np.isfinite(r.acc[k])), k
        # accounting: folded + quarantined partitions the campaign
        q_pts = sum(q["points"] for q in r.quarantined_chunks)
        assert r.totals["points"] + q_pts == N_POINTS
        assert r.totals["quarantined_points"] == q_pts
        man = json.loads((tmp_path / "manifest.json").read_text())
        assert man["quarantined"] == r.quarantined_chunks

    def test_clean_grid_quarantines_nothing(self, clean):
        assert clean.quarantined_chunks == []
        assert clean.totals["quarantined_points"] == 0
        assert clean.totals["points"] == N_POINTS


class TestCheckpointCorruption:
    def test_corrupt_checkpoint_detected_on_resume(self, grid,
                                                   tmp_path):
        plan = FaultPlan(seed=1, p_corrupt=1.0, max_per_chunk=1)
        with pytest.raises(CampaignKilled):
            campaign(grid, out_dir=str(tmp_path), checkpoint_every=1,
                     fault_plan=plan, _kill_after_chunks=3, **KW)
        # the manifest records the intended sha; the file is torn
        man = json.loads((tmp_path / "manifest.json").read_text())
        import hashlib
        disk = (tmp_path / "accumulator.npz").read_bytes()
        assert hashlib.sha256(disk).hexdigest() != man["acc_sha"]
        res = campaign(grid, out_dir=str(tmp_path),
                       checkpoint_every=1, fault_plan=plan,
                       resume=True, **KW)
        events = [e["event"] for e in res.fault_events]
        assert "checkpoint_corrupt" in events
        ref = campaign(grid, fault_plan=plan, **KW)
        assert res.fingerprint() == ref.fingerprint()

    def test_prev_generation_fallback(self, grid, clean, tmp_path):
        # pick a seed whose plan corrupts the LAST checkpoint (chunk
        # 3) but not the first (chunk 1): resume must fall back to
        # the rotated previous generation, not restart from zero
        seed = next(s for s in range(200)
                    if FaultPlan(seed=s, p_corrupt=0.5).roll(
                        "corrupt", 3)
                    and not FaultPlan(seed=s, p_corrupt=0.5).roll(
                        "corrupt", 1))
        plan = FaultPlan(seed=seed, p_corrupt=0.5)
        r = campaign(grid, out_dir=str(tmp_path), checkpoint_every=2,
                     fault_plan=plan, **KW)
        assert r.completed and r.n_chunks == 4
        res = campaign(grid, out_dir=str(tmp_path),
                       checkpoint_every=2, fault_plan=plan,
                       resume=True, **KW)
        recov = [e for e in res.fault_events
                 if e["event"] == "checkpoint_recovered"]
        assert recov and recov[0]["chunks_done"] == 2
        assert res.fingerprint() == clean.fingerprint()


class TestResumeParity:
    """The packaged witness: kill, resume, bitwise-compare."""

    def test_plain_kill_resume(self, grid, tmp_path):
        w = verify_resume(grid, out_dir=str(tmp_path),
                          kill_after_chunks=2, checkpoint_every=1,
                          **KW)
        assert w["match"] and w["killed_after"] == 2
        assert w["resumed_from"] == 2
        assert w["replayed_chunks"] == 2

    def test_kill_between_checkpoints_replays(self, grid, tmp_path):
        w = verify_resume(grid, out_dir=str(tmp_path),
                          kill_after_chunks=3, checkpoint_every=2,
                          **KW)
        # last checkpoint was after chunk 2 — chunk 3's work is lost
        # and replayed, bitwise
        assert w["match"] and w["resumed_from"] == 2

    def test_kill_resume_under_all_faults(self, grid, tmp_path):
        plan = FaultPlan(seed=9, p_dispatch=0.5, p_nan=0.3,
                         p_corrupt=0.5, max_per_chunk=2)
        w = verify_resume(grid, out_dir=str(tmp_path),
                          kill_after_chunks=3, checkpoint_every=1,
                          fault_plan=plan, fault_retries=4, **KW)
        assert w["match"]

    def test_kill_past_end_is_an_error(self, grid, tmp_path):
        with pytest.raises(ValueError, match="never fired"):
            verify_resume(grid, out_dir=str(tmp_path),
                          kill_after_chunks=99, **KW)

    def test_killed_exception_reports_progress(self, grid, tmp_path):
        with pytest.raises(CampaignKilled) as ei:
            campaign(grid, out_dir=str(tmp_path), checkpoint_every=1,
                     _kill_after_chunks=2, **KW)
        assert ei.value.chunks_drained == 2

    def test_resume_config_mismatch_still_refused(self, grid,
                                                  tmp_path):
        # the fault plan is part of the config fingerprint: resuming
        # with a DIFFERENT schedule would break parity silently
        plan = FaultPlan(seed=1, p_dispatch=0.2)
        with pytest.raises(CampaignKilled):
            campaign(grid, out_dir=str(tmp_path), checkpoint_every=1,
                     fault_plan=plan, _kill_after_chunks=2, **KW)
        with pytest.raises(ValueError, match="does not match"):
            campaign(grid, out_dir=str(tmp_path), resume=True,
                     fault_plan=FaultPlan(seed=2, p_dispatch=0.2),
                     checkpoint_every=1, **KW)
