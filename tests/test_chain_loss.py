"""Exact finite-waiting-room chain (``markov.solve_loss``) pins.

Three independent cross-checks of the q_max-room M/D[b]/1/q_max chain:

- banded / GTH structured solves vs the dense LU reference (≤ 1e-10,
  same chain, independent linear algebra) across b_max × load —
  including ρ > 1, where the *infinite*-room chain is not positive
  recurrent but the finite room makes every load a legitimate regime
  (no recurrence guard may trip: the band path itself must solve it),
- the MC sweep kernel's reject ("429") mode on a seed ladder (3σ),
- structural facts: loss fraction monotone decreasing in the room,
  renewal-reward sanity, and the metrics-layer K = q_max guard.
"""
import math

import numpy as np
import pytest

from repro.core import chain_solver, markov
from repro.core.analytic import LinearServiceModel
from repro.core.grid import SweepGrid
from repro.core.sweep import sweep

MODEL = LinearServiceModel(alpha=0.05, tau0=1.0)
B_MAXES = (1, 4, 32)
RHOS = (0.6, 0.9, 1.2)


def _lam(b_max: int, rho: float) -> float:
    return rho * b_max / (MODEL.alpha * b_max + MODEL.tau0)


class TestSolverParity:
    def test_band_and_gth_match_dense(self):
        """Same chain, three solvers, ≤ 1e-10 — across the full
        b_max × ρ × q_max cube, overload included."""
        for b_max in B_MAXES:
            for rho in RHOS:
                lam = _lam(b_max, rho)
                for q_max in (4, 24):
                    rd = markov.solve_loss(lam, MODEL, q_max=q_max,
                                           b_max=b_max, method="dense")
                    for meth in ("band", "gth"):
                        r = markov.solve_loss(lam, MODEL, q_max=q_max,
                                              b_max=b_max, method=meth)
                        assert r.method == meth
                        assert r.mean_latency == pytest.approx(
                            rd.mean_latency, rel=1e-10)
                        assert abs(r.loss_frac - rd.loss_frac) < 1e-10
                        assert abs(r.utilization
                                   - rd.utilization) < 1e-10

    def test_overload_stable_on_band_path(self):
        """ρ > 1 is the whole point of admission control: the banded
        path must solve it directly (no fallback, no guard trip), and
        the answers must be a proper loss equilibrium."""
        for b_max in B_MAXES:
            lam = _lam(b_max, 1.2)
            r = markov.solve_loss(lam, MODEL, q_max=16, b_max=b_max)
            assert r.method == "band"
            assert 0.0 < r.loss_frac < 1.0
            assert 0.0 < r.utilization <= 1.0 + 1e-12
            # the admitted rate must fit inside the service capacity
            cap = b_max / MODEL.tau(b_max)
            assert r.goodput <= cap * (1 + 1e-9)
            assert np.all(r.pi >= 0) and r.pi.sum() == pytest.approx(1.0)

    def test_infinite_bmax_room(self):
        """b_max = ∞ with a finite room: every completion drains the
        queue, so the loss comes only from within-service overflow."""
        r = markov.solve_loss(2.0, MODEL, q_max=8, b_max=math.inf)
        rd = markov.solve_loss(2.0, MODEL, q_max=8, b_max=math.inf,
                               method="dense")
        assert r.mean_latency == pytest.approx(rd.mean_latency,
                                               rel=1e-10)
        assert r.loss_frac < 0.05


class TestAgainstMC:
    def test_reject_mode_seed_ladder(self):
        """Exact chain vs the sweep kernel's q_max reject regime, per
        (b_max, ρ) cell on a seed ladder — all cells in ONE dispatched
        grid per seed."""
        cells = [(b, r) for b in B_MAXES for r in RHOS]
        q_max = 12
        g = SweepGrid.from_points([_lam(b, r) for b, r in cells],
                                  MODEL.alpha, MODEL.tau0,
                                  b_max=[b for b, _ in cells],
                                  q_max=q_max, overflow="reject")
        n_seeds = 5
        W = np.empty((n_seeds, len(cells)))
        L = np.empty((n_seeds, len(cells)))
        for s in range(n_seeds):
            res = sweep(g, n_batches=8000, q_cap=64, a_cap=64,
                        seed=100 + s)
            W[s], L[s] = res.mean_latency, res.reject_frac
        for i, (b_max, rho) in enumerate(cells):
            ex = markov.solve_loss(_lam(b_max, rho), MODEL,
                                   q_max=q_max, b_max=b_max)
            se_w = max(W[:, i].std(ddof=1) / math.sqrt(n_seeds),
                       0.01 * ex.mean_latency)
            se_l = max(L[:, i].std(ddof=1) / math.sqrt(n_seeds), 0.003)
            assert abs(W[:, i].mean() - ex.mean_latency) < 3.0 * se_w, \
                (b_max, rho, "mean_latency")
            assert abs(L[:, i].mean() - ex.loss_frac) < 3.0 * se_l, \
                (b_max, rho, "loss_frac")

    def test_evaluate_markov_backend_routes_loss_points(self):
        g = SweepGrid.from_points([_lam(4, 1.2)], MODEL.alpha,
                                  MODEL.tau0, b_max=[4], q_max=[12],
                                  overflow="reject")
        from repro.core.evaluate import evaluate
        (r,) = evaluate(g, backend="markov")
        # the grid stores λ/α/τ0 in float32 — compare at stored values
        ex = markov.solve_loss(
            float(g.lam[0]),
            LinearServiceModel(float(g.alpha[0]), float(g.tau0[0])),
            q_max=12, b_max=4)
        assert r.reject_frac == pytest.approx(ex.loss_frac, rel=1e-12)
        assert r.goodput == pytest.approx(ex.goodput, rel=1e-12)
        assert r.throughput == pytest.approx(ex.goodput, rel=1e-12)
        r.check()


class TestStructure:
    def test_loss_monotone_in_room(self):
        lam = _lam(4, 1.1)
        losses = [markov.solve_loss(lam, MODEL, q_max=q, b_max=4
                                    ).loss_frac
                  for q in (2, 4, 8, 16, 32)]
        assert all(a > b - 1e-12 for a, b in zip(losses, losses[1:]))
        # overload floor: an infinite room cannot push loss below
        # 1 − capacity/λ
        floor = 1.0 - (4 / MODEL.tau(4)) / lam
        assert losses[-1] > floor - 1e-9

    def test_large_room_approaches_lossless_chain(self):
        lam = _lam(4, 0.7)
        r = markov.solve_loss(lam, MODEL, q_max=64, b_max=4)
        m = markov.solve(lam, MODEL, b_max=4)
        assert r.loss_frac < 1e-8
        assert r.mean_latency == pytest.approx(m.mean_latency, rel=1e-6)
        assert r.mean_batch == pytest.approx(m.mean_batch, rel=1e-6)

    def test_metrics_layer_guard_and_validation(self):
        with pytest.raises(ValueError):
            markov.solve_loss(1.0, MODEL, q_max=0)
        with pytest.raises(ValueError):
            markov.solve_loss(-1.0, MODEL, q_max=4)
        with pytest.raises(ValueError):
            markov.solve_loss(1.0, MODEL, q_max=4, method="nope")
        with pytest.raises(ValueError):
            markov.solve_loss(1.0, MODEL, q_max=4, b_max=0)
        ch = chain_solver.build_chain(1.0, MODEL, 4, K=16)
        pi = chain_solver.solve_pi(ch)
        with pytest.raises(ValueError):
            # the loss reward structure only makes sense when the
            # truncation IS the room
            chain_solver.chain_loss_metrics(1.0, pi, ch.t_of, ch.b_of,
                                            q_max=8)
