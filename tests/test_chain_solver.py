"""Structured exact-chain solver: parity, witnesses, and guards.

The banded level-recursion solver (``repro.core.chain_solver``) must be
*indistinguishable* from the dense LU reference it replaced — the
parity matrix below pins it to ≤ 1e-10 on both π and E[W] across
load regimes, b_max ladders (including an ∞-proxy), and service-model
fits — and its three entry points (scalar ``solve``, warm-started
``solve_batch``, one-dispatch ``solve_grid``) must agree with each
other to the same tolerance.
"""
import math

import numpy as np
import pytest

from repro.core import chain_solver as cs
from repro.core import markov as mk
from repro.core.analytic import LinearServiceModel, stability_limit
from repro.core.evaluate import evaluate
from repro.core.grid import MarkovGrid

V100 = LinearServiceModel(alpha=0.1438, tau0=1.8874)   # published fits
P4 = LinearServiceModel(alpha=0.5833, tau0=1.4284)
SYNTH = LinearServiceModel(alpha=0.31, tau0=0.57)      # plain affine

MODELS = [("v100", V100), ("p4", P4), ("synth", SYNTH)]


def _lam(model, b_max, rho):
    return rho * stability_limit(model.alpha, model.tau0, b_max)


class TestStructuredVsDense:
    """The acceptance parity matrix: structured == dense LU ≤ 1e-10
    on E[W] and π, at the same truncation."""

    @pytest.mark.parametrize("name,model", MODELS)
    @pytest.mark.parametrize("b_max", [1, 4, 32])
    @pytest.mark.parametrize("rho", [0.2, 0.6, 0.9])
    def test_parity(self, name, model, b_max, rho):
        lam = _lam(model, b_max, rho)
        rs = mk.solve(lam, model, b_max=b_max, truncation=512,
                      method="struct")
        rd = mk.solve(lam, model, b_max=b_max, truncation=512,
                      method="dense")
        assert rs.method == "struct" and rd.method == "dense"
        assert rs.mean_latency == pytest.approx(rd.mean_latency,
                                                rel=1e-10)
        assert np.max(np.abs(rs.pi - rd.pi)) <= 1e-10
        assert rs.utilization == pytest.approx(rd.utilization, rel=1e-10)
        assert rs.mean_batch == pytest.approx(rd.mean_batch, rel=1e-10)

    def test_parity_inf_proxy(self):
        """b_max = 256 at a λ far below the cap is an ∞-proxy: the
        chain never meets its cap, so the structured answer must also
        match the *actual* b_max = ∞ dense solve."""
        lam = 0.6 / V100.alpha                        # ρ = 0.6
        rs = mk.solve(lam, V100, b_max=256, truncation=1024,
                      method="struct")
        rd = mk.solve(lam, V100, b_max=256, truncation=1024,
                      method="dense")
        rinf = mk.solve(lam, V100, truncation=1024)   # ∞ → dense path
        assert rs.mean_latency == pytest.approx(rd.mean_latency,
                                                rel=1e-10)
        assert rs.mean_latency == pytest.approx(rinf.mean_latency,
                                                rel=1e-9)

    def test_gth_equals_banded_lapack(self):
        """The two CPU paths over the same band agree near machine
        precision (they are different factorizations of one matrix)."""
        lam = _lam(V100, 32, 0.9)
        ch = cs.build_chain(lam, V100, 32, 1024)
        pi_g = cs.solve_pi_gth(ch)
        pi_b = cs.solve_pi_banded(ch)
        assert np.max(np.abs(pi_g - pi_b)) <= 1e-13


class TestThreeWayAgreement:
    """solve vs solve_batch vs vmapped-JAX solve_grid."""

    def test_scalar_vs_batch_vs_grid(self):
        b_maxes = [2, 8, 32]
        fracs = [0.3, 0.7, 0.9]
        grid = MarkovGrid.from_fracs(fracs, V100.alpha, V100.tau0,
                                     b_maxes=b_maxes)
        K = 512
        gj = mk.solve_grid(grid, truncation=K, method="jax")
        gn = mk.solve_grid(grid, truncation=K, method="numpy")
        assert np.max(np.abs(gj.mean_latency - gn.mean_latency)
                      / gn.mean_latency) <= 1e-10
        for b in b_maxes:
            sel = grid.b_max == b
            lams = grid.lam[sel]
            batch = mk.solve_batch(list(lams), V100, b_max=b,
                                   truncation=K)
            for j, (lam, rb) in enumerate(zip(lams, batch)):
                rs = mk.solve(float(lam), V100, b_max=b, truncation=K)
                i = int(np.nonzero(sel)[0][j])
                assert rs.mean_latency == pytest.approx(
                    rb.mean_latency, rel=1e-12)
                assert rs.mean_latency == pytest.approx(
                    float(gj.mean_latency[i]), rel=1e-10)
                assert rs.tail_mass == pytest.approx(
                    float(gj.tail_mass[i]), abs=1e-12)

    def test_grid_jax_low_load_wide_bmax(self):
        """Regression: cells whose Poisson window is narrower than
        b_max (low load, large cap) must still dispatch — the down-move
        span D is clamped to the band width."""
        grid = MarkovGrid.from_fracs([0.1, 0.2], V100.alpha, V100.tau0,
                                     b_maxes=[128])
        gj = mk.solve_grid(grid, truncation=512, method="jax")
        gn = mk.solve_grid(grid, truncation=512, method="numpy")
        assert np.max(np.abs(gj.mean_latency - gn.mean_latency)
                      / gn.mean_latency) <= 1e-10

    def test_evaluate_markov_grid_backend(self):
        grid = MarkovGrid.from_fracs([0.4, 0.8], V100.alpha, V100.tau0,
                                     b_maxes=[4, 16])
        res = evaluate(grid, backend="markov", method="numpy")
        assert len(res) == 4
        for i, r in enumerate(res):
            ref = mk.solve(float(grid.lam[i]), V100,
                           b_max=float(grid.b_max[i]))
            assert r.backend == "markov"
            assert r.mean_latency == pytest.approx(ref.mean_latency,
                                                   rel=1e-8)
            r.check()

    def test_evaluate_rejects_markov_grid_elsewhere(self):
        grid = MarkovGrid.from_fracs([0.5], V100.alpha, V100.tau0,
                                     b_maxes=[4])
        with pytest.raises(ValueError, match="markov"):
            evaluate(grid, backend="sweep")


class TestTruncationWitness:
    """π[K] is the a-posteriori truncation witness; growing K must
    drive it down (to zero once the band clears the bulk)."""

    def test_tail_mass_monotone_under_K_growth(self):
        lam = _lam(V100, 32, 0.95)
        tails = [mk.solve(lam, V100, b_max=32, truncation=K,
                          method="struct").tail_mass
                 for K in (128, 256, 512, 1024)]
        for a, b in zip(tails, tails[1:]):
            assert b <= a * 1.01 + 1e-300
        assert tails[-1] < 1e-12

    def test_adaptive_meets_tolerance(self):
        lam = _lam(V100, 16, 0.9)
        r = mk.solve(lam, V100, b_max=16, tail_tol=1e-10)
        assert r.method == "struct"
        assert r.tail_mass <= 1e-10

    def test_grid_adaptive_meets_tolerance(self):
        grid = MarkovGrid.from_fracs([0.5, 0.95], V100.alpha, V100.tau0,
                                     b_maxes=[8, 64])
        res = mk.solve_grid(grid, method="numpy")
        assert float(res.tail_mass.max()) <= 1e-10


class TestGuardsAndDomain:
    """The truncation caps are per-method now: dense keeps the hard
    0.5 GB guard, the structured path goes far deeper."""

    def test_dense_hard_cap_still_raises(self):
        with pytest.raises(ValueError, match="dense"):
            mk.solve(1.0, V100, b_max=8, truncation=20_000,
                     method="dense")
        with pytest.raises(ValueError):
            mk.solve(1.0, V100, truncation=20_000)    # ∞ → dense

    def test_structured_goes_past_the_dense_cap(self):
        # 32768 would be an 8.6 GB dense matrix; the band is ~20 MB
        lam = _lam(V100, 4, 0.5)
        r = mk.solve(lam, V100, b_max=4, truncation=32_768,
                     method="struct")
        ref = mk.solve(lam, V100, b_max=4)
        assert r.truncation == 32_768
        assert r.mean_latency == pytest.approx(ref.mean_latency,
                                               rel=1e-9)

    def test_band_detachment_raises_and_auto_falls_back(self):
        lam = 2.0 * stability_limit(V100.alpha, V100.tau0, 256)
        with pytest.raises(ValueError, match="dense"):
            mk.solve(lam, V100, b_max=256, truncation=256,
                     method="struct")
        r = mk.solve(lam, V100, b_max=256, truncation=256)  # auto
        assert r.method == "dense"

    def test_solve_batch_auto_falls_back_like_solve(self):
        """Regression: solve and solve_batch must stay interchangeable
        — an out-of-domain λ falls back to dense in both, and in-domain
        λs in the same batch stay structured."""
        lim = stability_limit(V100.alpha, V100.tau0, 256)
        lams = [0.5 * lim, 2.0 * lim]
        batch = mk.solve_batch(lams, V100, b_max=256, truncation=256)
        assert batch[0].method == "struct"
        assert batch[1].method == "dense"
        for lam, rb in zip(lams, batch):
            rs = mk.solve(lam, V100, b_max=256, truncation=256)
            assert rb.mean_latency == pytest.approx(rs.mean_latency,
                                                    rel=1e-10)

    def test_markov_grid_requires_finite_bmax(self):
        with pytest.raises(ValueError, match="finite"):
            MarkovGrid.from_points([1.0], V100.alpha, V100.tau0,
                                   b_max=0)

    def test_grid_rejects_out_of_domain_cell(self):
        lam = 2.0 * stability_limit(V100.alpha, V100.tau0, 256)
        grid = MarkovGrid.from_points([lam], V100.alpha, V100.tau0,
                                      b_max=256)
        with pytest.raises(ValueError, match="domain"):
            mk.solve_grid(grid, truncation=256, method="numpy")


class TestBandConstruction:
    """Structural invariants of the band the recursions rely on."""

    def test_rows_are_stochastic_and_banded(self):
        lam = _lam(V100, 16, 0.8)
        ch = cs.build_chain(lam, V100, 16, 512)
        assert ch.B.shape == (513, ch.V + 1)
        np.testing.assert_allclose(ch.B.sum(axis=1), 1.0, atol=1e-12)
        assert np.all(np.diff(ch.c) >= 0)             # monotone offsets
        assert np.all(ch.c[1:] < np.arange(1, 513))   # attached band
        # repeating region: identical Toeplitz rows (shifted by 1)
        mid = 100
        np.testing.assert_allclose(ch.B[mid], ch.B[mid + 1], atol=0)

    def test_band_matches_dense_rows(self):
        lam = _lam(V100, 8, 0.7)
        K = 256
        ch = cs.build_chain(lam, V100, 8, K)
        s = mk._ChainStructure(V100, 8, K)
        P = mk._transition_matrix(lam, s, K)
        dense_from_band = np.zeros((K + 1, K + 1))
        for l in range(K + 1):
            w = ch.width[l]
            dense_from_band[l, ch.c[l]:ch.c[l] + w + 1] = ch.B[l, :w + 1]
        assert np.max(np.abs(dense_from_band - P)) < 1e-15


class TestX64Discipline:
    """S1: the grid kernel's build-time constants are baked into the
    trace, so the builder must run inside an enable_x64 scope — the
    PR 4 footgun (silent float32 truncation) is now a build error."""

    def test_build_outside_x64_raises(self):
        cs._build_grid_kernel.cache_clear()
        with pytest.raises(RuntimeError, match="enable_x64"):
            cs._build_grid_kernel(64, 16, 8)
        assert cs._build_grid_kernel.cache_len() == 0

    def test_every_band_path_output_is_float64(self):
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        with enable_x64():
            kernel = cs._build_grid_kernel(64, 16, 8)
            out = jax.eval_shape(
                kernel,
                jnp.zeros((2,), jnp.float64),
                jnp.zeros((2,), jnp.float64),
                jnp.zeros((2,), jnp.float64),
                jnp.zeros((2,), jnp.int32))
            bad = {k: v.dtype for k, v in out.items()
                   if v.dtype != jnp.float64}
            assert not bad, f"float64 dropped in: {bad}"

    def test_grid_solve_builds_inside_x64_and_stays_exact(self):
        """grid_solve (which owns the enable_x64 scope) must agree
        with the pure-NumPy float64 solver to near machine precision —
        any float32 intermediate on the band path would blow this
        tolerance by ~8 orders of magnitude."""
        cs._build_grid_kernel.cache_clear()
        lam = _lam(V100, 8, 0.9)
        out_j = cs.grid_solve([lam], [V100.alpha], [V100.tau0], [8],
                              256, method="jax")
        out_n = cs.grid_solve([lam], [V100.alpha], [V100.tau0], [8],
                              256, method="numpy")
        for k in out_j:
            # tail_mass is O(1e-23): summation-order noise alone moves
            # it at the ~1e-10 level, so it gets a slightly looser rel
            rel = 1e-6 if k == "tail_mass" else 1e-10
            assert out_j[k][0] == pytest.approx(out_n[k][0],
                                                rel=rel, abs=1e-300)
