"""Beyond-paper continuous-batching: simulator properties + real engine."""
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.continuous_sim import (GenServiceModel, simulate_continuous,
                                       simulate_static_generate)
from repro.serving.continuous import ContinuousEngine

MODEL = GenServiceModel(alpha_decode=0.14, tau0_decode=1.9,
                        alpha_prefill=0.035, tau0_prefill=1.9)


class TestSimulator:
    def test_latency_floor(self):
        """E[W] ≥ the solo service time at any load, both disciplines."""
        floor = MODEL.prefill(128) + 32 * MODEL.decode_step(1)
        for sim in (simulate_continuous, simulate_static_generate):
            r = sim(0.001, MODEL, prompt_len=128, gen_tokens=32,
                    n_jobs=2000, seed=0)
            assert r.mean_latency >= floor * 0.6

    def test_monotone_in_load(self):
        for sim in (simulate_continuous, simulate_static_generate):
            prev = 0.0
            for lam in (0.01, 0.05, 0.1):
                r = sim(lam, MODEL, n_jobs=5000, seed=1)
                assert r.mean_latency >= prev * 0.9
                prev = r.mean_latency

    def test_continuous_wins_light_load(self):
        """Iteration-level scheduling avoids head-of-line blocking when the
        server is lightly loaded."""
        lam = 0.03
        st = simulate_static_generate(lam, MODEL, n_jobs=8000, seed=2)
        ct = simulate_continuous(lam, MODEL, n_jobs=8000, seed=2)
        assert ct.mean_latency < st.mean_latency

    def test_static_amortizes_prefill_high_load(self):
        """The beyond-paper finding: with inline (non-chunked) prefill and
        linear service, batch-all-waiting amortizes prefill τ0 better near
        saturation."""
        cap = 1.0 / (32 * MODEL.alpha_decode + 128 * MODEL.alpha_prefill)
        st = simulate_static_generate(0.8 * cap, MODEL, n_jobs=12000,
                                      seed=3)
        ct = simulate_continuous(0.8 * cap, MODEL, n_jobs=12000, seed=3)
        assert st.mean_latency < ct.mean_latency

    def test_active_bounded(self):
        r = simulate_continuous(0.1, MODEL, max_active=16, n_jobs=4000,
                                seed=4)
        assert r.mean_active <= 16


@pytest.mark.slow
def test_real_engine_runs():
    cfg = reduced(get_config("qwen1.5-0.5b"))
    eng = ContinuousEngine(cfg, prompt_len=8, gen_tokens=4, max_active=4)
    res = eng.serve_poisson(lam=20.0, n_jobs=12, seed=0)
    assert res.n_jobs == 12
    assert res.mean_latency > 0
    assert 1 <= res.mean_active <= 4
    assert (res.latencies > 0).all()


class TestReplicaEconomics:
    """Beyond-paper replica/consolidation analysis (core/replicas.py)."""

    def test_scaleup_consolidation_dominates(self):
        from repro.core.analytic import LinearServiceModel
        from repro.core.replicas import compare
        V100 = LinearServiceModel(0.1438, 1.8874)
        for rho in (0.2, 0.5, 0.8):
            c = compare(rho / V100.alpha, V100, 4, tau0_scaling="scaled")
            # a perfectly scaled-up server strictly beats splitting
            assert c.ew_consolidated < c.ew_split

    def test_jsq_runs_and_is_sane(self):
        from repro.core.analytic import LinearServiceModel
        from repro.core.replicas import simulate_jsq
        from repro.core.markov import solve
        V100 = LinearServiceModel(0.1438, 1.8874)
        lam = 0.5 / V100.alpha
        jsq = simulate_jsq(lam, V100, 4, n_jobs=30_000, seed=1)
        solo = solve(lam / 4, V100).mean_latency
        # JSQ across 4 replicas lands in the same regime as a 1/4 split
        assert 0.5 * solo < jsq < 2.0 * solo
