"""Deterministic-trace regression tests for the serving engine's event
loop (repro.serving.engine.serve_poisson).

A stub engine replaces the real JAX model with the deterministic linear
service law τ(b) = α·b + τ0, so the *event ordering* of serve_poisson
can be pinned exactly:

- under the paper's batch-all-waiting policy it must reproduce the
  scalar reference simulator (core/simulate.py) job-for-job — both draw
  the same Poisson trace from the same seed, so latencies and batch
  sizes must agree to float precision, not statistically;
- under TimeoutBatch, every arrival landing inside the policy-delay
  window must join the forming batch (the admission rule the engine
  implements between release_time() and the batch take()).
"""
import numpy as np
import pytest

from repro.core.analytic import LinearServiceModel
from repro.core.policy import BatchAllWaiting, TimeoutBatch
from repro.core.simulate import simulate
from repro.serving.engine import InferenceEngine

V100 = LinearServiceModel(alpha=0.1438, tau0=1.8874)


class _TraceEngine(InferenceEngine):
    """serve_poisson's event loop over virtual deterministic service
    times — no model is built, no JAX execution happens."""

    def __init__(self, model: LinearServiceModel, max_batch: int = 256):
        self.model = model
        self.max_batch = max_batch
        self.buckets = [max_batch]

    def run_batch(self, b: int) -> float:
        return float(self.model.tau(b))


def test_batch_all_waiting_matches_scalar_simulator_exactly():
    """Same seed ⇒ same Poisson trace in both implementations; with
    deterministic service the whole event ordering must coincide."""
    lam, n = 0.5 / V100.alpha, 400
    eng = _TraceEngine(V100)
    res = eng.serve_poisson(lam, n_jobs=n, policy=BatchAllWaiting(),
                            seed=3, warmup=False)
    ref = simulate(lam, V100, n_jobs=n, seed=3, warmup_frac=0.0,
                   keep_latencies=True)
    # simulate() runs until >= n jobs depart; compare the common prefix
    bs_ref = []
    total = 0
    for b in ref.batch_sizes:
        if total + b > n:
            break
        bs_ref.append(b)
        total += b
    m = len(bs_ref)
    assert m > 10
    assert list(res.batch_sizes[:m]) == bs_ref
    np.testing.assert_allclose(res.latencies[:total],
                               ref.latencies[:total], rtol=1e-9)
    assert res.mean_batch >= 1.0


def test_timeout_delay_window_admission():
    """Arrivals in (first_arrival, first_arrival + max_wait] must join
    the forming batch — recomputed independently from the known
    trace."""
    lam, n, seed = 2.0, 64, 11
    W, target, cap = 1.5, 32, 8
    eng = _TraceEngine(V100)
    res = eng.serve_poisson(lam, n_jobs=n,
                            policy=TimeoutBatch(max_wait=W, target=target,
                                                cap=cap),
                            seed=seed, warmup=False)

    arrivals = np.cumsum(
        np.random.default_rng(seed).exponential(1.0 / lam, size=n))
    t1 = arrivals[0]
    start = t1 + W                       # 1 < target ⇒ full delay
    members = arrivals[arrivals <= start][:cap]
    b0 = len(members)
    assert b0 > 1, "trace must put arrivals inside the delay window"
    assert res.batch_sizes[0] == b0
    depart = start + float(V100.tau(b0))
    np.testing.assert_allclose(res.latencies[:b0], depart - members,
                               rtol=1e-9)


def test_event_ordering_invariants_under_timeout():
    """Per batch: one departure epoch for all members, and no member
    arrives after its batch starts service (admission closes at the
    release, never later)."""
    lam, n, seed = 3.0, 200, 5
    pol = TimeoutBatch(max_wait=0.8, target=6, cap=16)
    eng = _TraceEngine(V100)
    res = eng.serve_poisson(lam, n_jobs=n, policy=pol, seed=seed,
                            warmup=False)
    arrivals = np.cumsum(
        np.random.default_rng(seed).exponential(1.0 / lam, size=n))
    o = 0
    for b in res.batch_sizes:
        if o + b > n:
            break
        mem = arrivals[o:o + b]
        departs = res.latencies[o:o + b] + mem
        assert np.ptp(departs) < 1e-9          # one departure per batch
        start = departs[0] - float(V100.tau(b))
        assert mem.max() <= start + 1e-9       # admitted before service
        assert b <= pol.cap
        o += b
    assert o >= n - pol.cap


def test_stub_engine_bucketing_untouched():
    """The stub bypasses bucket padding, so batch cost is exactly τ(b) —
    guard against the stub accidentally exercising model paths."""
    eng = _TraceEngine(V100, max_batch=32)
    assert eng.run_batch(5) == pytest.approx(float(V100.tau(5)))
    assert eng.bucket_of(7) == 32
