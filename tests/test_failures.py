"""Regression harness for the breakdown/repair (server-failure) paths.

Every failure discipline of the three MC kernels — *preempt-resume*
(work survives the outage), *preempt-restart* (the in-flight batch
re-executes from scratch), *fail-drop* (the in-flight batch aborts
and its jobs enter the loss/retry accounting) — is pinned against the
independent chronological numpy mirrors in ``repro.core.loss_ref`` on
seed ladders (3σ of the paired MC error, house convention), plus:

- **MTBF→∞ reduction**: a ``mtbf=0`` point dispatched through the
  failure-capable kernel is bitwise identical to the base kernel at
  pinned caps — the breakdown machinery must cost *nothing* on
  reliable points (the salted failure key stream never perturbs the
  arrival/service draws).
- **Chain-vs-MC**: the completion-time transform in ``markov.solve``
  (resume and restart) agrees with the failing MC kernel within 3σ
  on a seed ladder, and its availability matches to ~1e-2.
- **Exact accounting**: the goodput partition still sums to 1 with
  failures on, availability ∈ (0, 1], resume loses no work, restart
  does, span and failure counts are consistent.
- **Capacity headroom (S1)**: ``engine.queue_capacity`` sized with
  the completion-time law keeps ``buffer_dropped == 0`` at MTTR up
  to 10·τ[b_max], for resume AND restart.
- **ρ_eff diagnostic (S6)**: an unstable failure point raises a
  ValueError naming ρ_eff and the (MTBF, MTTR) pair, not an opaque
  recurrence error.
"""
import math

import numpy as np
import pytest

from repro.core import engine, markov
from repro.core.analytic import LinearServiceModel
from repro.core.continuous_sim import GenServiceModel
from repro.core.gen_sweep import gen_sweep
from repro.core.grid import FleetGrid, GenGrid, SweepGrid
from repro.core.loss_ref import (simulate_fleet_loss_numpy,
                                 simulate_gen_loss_numpy,
                                 simulate_loss_numpy)
from repro.core.sweep import fleet_sweep, sweep

MODEL = LinearServiceModel(alpha=0.05, tau0=1.0)
GMODEL = GenServiceModel(alpha_decode=0.14, tau0_decode=1.9,
                         alpha_prefill=0.035, tau0_prefill=1.9)
GEN, PROMPT, CAP = 32, 128, 64
ALPHA_EQ = GMODEL.alpha_decode * GEN + GMODEL.alpha_prefill * PROMPT

N_REPS = 6                  # ladder width on the kernel side
N_REF = 3                   # seeds on the numpy-reference side
FAIL_FIELDS = ("mean_latency", "utilization", "availability",
               "work_loss_frac")

# (fail_disc, mtbf, mttr, throttle, lam) — one config per discipline,
# mtbf a few service times so outages actually fire, drop with a
# degraded-phase throttle so that path is exercised too
SW_CFG = [("resume", 8.0, 0.5, 1.0, 4.0),
          ("restart", 8.0, 0.5, 1.0, 4.0),
          ("drop", 8.0, 0.5, 0.85, 4.0)]
SW_BMAX = 8
FL_CFG = [("resume", "jsq"), ("restart", "random"),
          ("drop", "round_robin")]
FL_LAM, FL_K, FL_B, FL_MTBF, FL_MTTR = 6.0, 2, 4, 8.0, 0.5
GEN_LAM = 0.7 / ALPHA_EQ
GEN_CFG = [("resume", 200.0, 5.0), ("restart", 200.0, 5.0),
           ("drop", 200.0, 5.0)]


def _ladder_se(kernel_vals, ref_vals, floor_frac=0.015,
               floor_abs=0.0):
    se = math.sqrt(kernel_vals.var(ddof=1) / len(kernel_vals)
                   + ref_vals.var(ddof=1) / len(ref_vals))
    return max(se, floor_frac * abs(float(ref_vals.mean())), floor_abs)


def _gate(kernel_vals, ref_vals, label):
    se = _ladder_se(kernel_vals, ref_vals, floor_abs=0.004)
    assert abs(kernel_vals.mean() - ref_vals.mean()) < 3.0 * se, \
        (label, float(kernel_vals.mean()), float(ref_vals.mean()))


@pytest.fixture(scope="module")
def sweep_fail():
    cfg = [c for c in SW_CFG for _ in range(N_REPS)]
    g = SweepGrid.from_points([c[4] for c in cfg], MODEL.alpha,
                              MODEL.tau0, b_max=SW_BMAX,
                              fail_disc=[c[0] for c in cfg],
                              mtbf=[c[1] for c in cfg],
                              mttr=[c[2] for c in cfg],
                              throttle=[c[3] for c in cfg])
    assert g.has_fail
    return g, sweep(g, n_batches=6000, q_cap=64, a_cap=64, r_cap=64,
                    seed=11)


@pytest.fixture(scope="module")
def fleet_fail():
    cfg = [c for c in FL_CFG for _ in range(N_REPS)]
    g = FleetGrid.from_points([FL_LAM] * len(cfg), MODEL.alpha,
                              MODEL.tau0, k=FL_K, b_max=FL_B,
                              routing=[c[1] for c in cfg],
                              fail_disc=[c[0] for c in cfg],
                              mtbf=FL_MTBF, mttr=FL_MTTR)
    return g, fleet_sweep(g, n_steps=8000, q_cap=64, a_cap=32,
                          r_cap=64, seed=7)


@pytest.fixture(scope="module")
def gen_fail():
    cfg = [c for c in GEN_CFG for _ in range(N_REPS)]
    g = GenGrid.from_points(
        [GEN_LAM] * len(cfg), GMODEL.alpha_decode, GMODEL.tau0_decode,
        GMODEL.alpha_prefill, GMODEL.tau0_prefill, prompt_len=PROMPT,
        gen_tokens=GEN, max_active=CAP,
        fail_disc=[c[0] for c in cfg], mtbf=[c[1] for c in cfg],
        mttr=[c[2] for c in cfg])
    return g, gen_sweep(g, n_steps=6000, q_cap=96, a_cap=96, r_cap=64,
                        seed=5)


class TestSweepVsNumpyRef:
    @pytest.mark.parametrize("ci", range(len(SW_CFG)))
    def test_failure_metrics_seed_ladder(self, sweep_fail, ci):
        _, r = sweep_fail
        disc, mtbf, mttr, thr, lam = SW_CFG[ci]
        sl = slice(ci * N_REPS, (ci + 1) * N_REPS)
        refs = [simulate_loss_numpy(lam, MODEL, SW_BMAX, mtbf=mtbf,
                                    mttr=mttr, fail_disc=disc,
                                    throttle=thr, q_cap=64, r_cap=64,
                                    n_batches=15_000, seed=s)
                for s in range(N_REF)]
        for f in FAIL_FIELDS:
            _gate(np.asarray(getattr(r, f)[sl], dtype=float),
                  np.array([getattr(x, f) for x in refs]),
                  (disc, f))


class TestFleetVsNumpyRef:
    @pytest.mark.parametrize("ci", range(len(FL_CFG)))
    def test_failure_metrics_seed_ladder(self, fleet_fail, ci):
        _, r = fleet_fail
        disc, route = FL_CFG[ci]
        sl = slice(ci * N_REPS, (ci + 1) * N_REPS)
        refs = [simulate_fleet_loss_numpy(FL_LAM, MODEL, FL_B, k=FL_K,
                                          routing=route, mtbf=FL_MTBF,
                                          mttr=FL_MTTR, fail_disc=disc,
                                          q_cap=64, r_cap=64,
                                          n_events=40_000, seed=s)
                for s in range(N_REF)]
        for f in FAIL_FIELDS:
            _gate(np.asarray(getattr(r, f)[sl], dtype=float),
                  np.array([getattr(x, f) for x in refs]),
                  (disc, route, f))


class TestGenVsNumpyRef:
    @pytest.mark.parametrize("ci", range(len(GEN_CFG)))
    def test_failure_metrics_seed_ladder(self, gen_fail, ci):
        _, r = gen_fail
        disc, mtbf, mttr = GEN_CFG[ci]
        sl = slice(ci * N_REPS, (ci + 1) * N_REPS)
        refs = [simulate_gen_loss_numpy(GEN_LAM, GMODEL,
                                        prompt_len=PROMPT,
                                        gen_tokens=GEN, max_active=CAP,
                                        mtbf=mtbf, mttr=mttr,
                                        fail_disc=disc, q_cap=96,
                                        r_cap=64, n_steps=20_000,
                                        seed=s)
                for s in range(N_REF)]
        for f in FAIL_FIELDS:
            _gate(np.asarray(getattr(r, f)[sl], dtype=float),
                  np.array([getattr(x, f) for x in refs]),
                  (disc, f))


class TestAccounting:
    """Exact (not statistical) invariants on every failure run."""

    def _check(self, r, n_cycles):
        assert int(r.buffer_dropped.sum()) == 0
        av = np.asarray(r.availability, dtype=float)
        assert np.all((av > 0.0) & (av <= 1.0))
        wl = np.asarray(r.work_loss_frac, dtype=float)
        assert np.all((wl >= 0.0) & (wl < 1.0))
        assert np.all(np.asarray(r.span, dtype=float) > 0.0)
        assert np.all(np.asarray(r.n_failures) > 0)
        assert np.all(np.asarray(r.down_time, dtype=float) > 0.0)

    def test_sweep(self, sweep_fail):
        _, r = sweep_fail
        self._check(r, 6000)
        lost = np.asarray(r.lost_work, dtype=float)
        # resume loses no work; restart re-executes; drop abandons
        assert np.all(lost[0 * N_REPS:1 * N_REPS] == 0.0)
        assert np.all(lost[1 * N_REPS:2 * N_REPS] > 0.0)
        assert np.all(lost[2 * N_REPS:3 * N_REPS] > 0.0)
        # fail-drop files its aborted jobs — goodput partition holds
        sl = slice(2 * N_REPS, 3 * N_REPS)
        offered = (r.n_jobs + r.overflow_dropped + r.abandoned)[sl]
        total = (r.goodput_frac + r.late_frac + r.reject_frac
                 + r.abandon_frac)[sl]
        assert np.all(offered > 0)
        assert np.allclose(total, 1.0, atol=1e-6)
        assert np.all(r.abandoned[sl] > 0)

    def test_fleet(self, fleet_fail):
        _, r = fleet_fail
        self._check(r, 8000)

    def test_gen(self, gen_fail):
        _, r = gen_fail
        self._check(r, 6000)


class TestMTBFInfReduction:
    """A reliable (mtbf=0) point must be BITWISE the base kernel's
    answer at pinned caps — even when dispatched alongside failing
    points through the failure-capable kernel, because the failure
    draws come from a salted side stream."""

    BASE_FIELDS = ("mean_latency", "mean_batch", "utilization",
                   "n_jobs", "latency_p50", "latency_p99")

    def test_sweep(self):
        g = SweepGrid.from_points(
            [4.0, 3.0, 2.0], MODEL.alpha, MODEL.tau0, b_max=SW_BMAX,
            fail_disc=["restart", "resume", "resume"],
            mtbf=[8.0, 0.0, 0.0], mttr=[0.5, 0.0, 0.0])
        assert g.has_fail and not g.take(slice(1, None)).has_fail
        kw = dict(n_batches=1024, q_cap=64, a_cap=64)
        mixed = sweep(g, seed=11, **kw)
        base = sweep(g.take(slice(1, None)), seed=11, key_offset=1,
                     **kw)
        for f in self.BASE_FIELDS:
            assert np.array_equal(getattr(mixed, f)[1:],
                                  getattr(base, f)), f
        assert np.all(np.asarray(mixed.availability)[1:] == 1.0)
        assert np.all(np.asarray(mixed.n_failures)[1:] == 0)

    def test_fleet(self):
        g = FleetGrid.from_points(
            [6.0, 5.0, 4.0], MODEL.alpha, MODEL.tau0, k=FL_K,
            b_max=FL_B, routing="jsq",
            fail_disc=["resume", "resume", "resume"],
            mtbf=[8.0, 0.0, 0.0], mttr=[0.5, 0.0, 0.0])
        kw = dict(n_steps=1024, q_cap=64, a_cap=16)
        mixed = fleet_sweep(g, seed=13, **kw)
        base = fleet_sweep(g.take(slice(1, None)), seed=13,
                           key_offset=1, **kw)
        for f in self.BASE_FIELDS:
            assert np.array_equal(getattr(mixed, f)[1:],
                                  getattr(base, f)), f

    def test_gen(self):
        g = GenGrid.from_points(
            [GEN_LAM, 0.8 * GEN_LAM, 0.6 * GEN_LAM],
            GMODEL.alpha_decode, GMODEL.tau0_decode,
            GMODEL.alpha_prefill, GMODEL.tau0_prefill,
            prompt_len=PROMPT, gen_tokens=GEN, max_active=CAP,
            fail_disc=["restart", "resume", "resume"],
            mtbf=[200.0, 0.0, 0.0], mttr=[5.0, 0.0, 0.0])
        kw = dict(n_steps=1024, q_cap=64, a_cap=96)
        mixed = gen_sweep(g, seed=13, **kw)
        base = gen_sweep(g.take(slice(1, None)), seed=13,
                         key_offset=1, **kw)
        for f in self.BASE_FIELDS:
            assert np.array_equal(getattr(mixed, f)[1:],
                                  getattr(base, f)), f


class TestSplitDispatchDeterminism:
    """Per-point bitwise invariance to dispatch grouping with the
    failure machinery armed — guards the salted fold_in key
    construction against shape-dependent key consumption."""

    def test_sweep(self):
        g = SweepGrid.from_points(
            [4.0, 3.5, 3.0, 2.5], MODEL.alpha, MODEL.tau0,
            b_max=SW_BMAX,
            fail_disc=["resume", "restart", "drop", "resume"],
            mtbf=[8.0, 8.0, 8.0, 0.0], mttr=[0.5, 0.5, 0.5, 0.0],
            throttle=[1.0, 0.85, 1.0, 1.0])
        kw = dict(n_batches=512, q_cap=64, a_cap=64, r_cap=32)
        full = sweep(g, seed=11, **kw)
        a = sweep(g.take(slice(0, 2)), seed=11, **kw)
        b = sweep(g.take(slice(2, None)), seed=11, key_offset=2, **kw)
        for f in ("mean_latency", "n_jobs", "n_failures", "down_time",
                  "lost_work", "utilization"):
            merged = np.concatenate([getattr(a, f), getattr(b, f)])
            assert np.array_equal(getattr(full, f), merged), f

    def test_fleet(self):
        g = FleetGrid.from_points(
            [6.0, 6.0, 5.0, 6.0], MODEL.alpha, MODEL.tau0,
            k=[2, 2, 1, 2],
            routing=["jsq", "random", "round_robin", "jsq"],
            b_max=FL_B,
            fail_disc=["resume", "restart", "drop", "resume"],
            mtbf=[8.0, 8.0, 8.0, 0.0], mttr=[0.5, 0.5, 0.5, 0.0])
        kw = dict(n_steps=512, q_cap=64, a_cap=16, r_cap=32)
        full = fleet_sweep(g, seed=13, **kw)
        a = fleet_sweep(g.take(slice(0, 2)), seed=13, **kw)
        b = fleet_sweep(g.take(slice(2, None)), seed=13, key_offset=2,
                        **kw)
        for f in ("mean_latency", "n_jobs", "n_failures", "down_time",
                  "lost_work"):
            merged = np.concatenate([getattr(a, f), getattr(b, f)])
            assert np.array_equal(getattr(full, f), merged), f

    def test_gen(self):
        g = GenGrid.from_points(
            [GEN_LAM] * 4, GMODEL.alpha_decode, GMODEL.tau0_decode,
            GMODEL.alpha_prefill, GMODEL.tau0_prefill,
            prompt_len=PROMPT, gen_tokens=GEN, max_active=CAP,
            fail_disc=["resume", "restart", "drop", "resume"],
            mtbf=[200.0, 200.0, 200.0, 0.0],
            mttr=[5.0, 5.0, 5.0, 0.0])
        kw = dict(n_steps=512, q_cap=64, a_cap=96, r_cap=32)
        full = gen_sweep(g, seed=13, **kw)
        a = gen_sweep(g.take(slice(0, 2)), seed=13, **kw)
        b = gen_sweep(g.take(slice(2, None)), seed=13, key_offset=2,
                      **kw)
        for f in ("mean_latency", "n_jobs", "n_failures", "down_time",
                  "lost_work"):
            merged = np.concatenate([getattr(a, f), getattr(b, f)])
            assert np.array_equal(getattr(full, f), merged), f


class TestChainVsMC:
    """The completion-time transform against the failing MC kernel —
    the exact-reference witness for the breakdown regime."""

    LAM, MTBF, MTTR = 3.0, 8.0, 0.5

    @pytest.mark.parametrize("disc", ["resume", "restart"])
    def test_latency_within_3_sigma(self, disc):
        ex = markov.solve(self.LAM, MODEL, b_max=SW_BMAX,
                          mtbf=self.MTBF, mttr=self.MTTR,
                          fail_disc=disc)
        n_lad = 8
        g = SweepGrid.from_points([self.LAM] * n_lad, MODEL.alpha,
                                  MODEL.tau0, b_max=SW_BMAX,
                                  fail_disc=disc, mtbf=self.MTBF,
                                  mttr=self.MTTR)
        r = sweep(g, n_batches=8000, q_cap=64, a_cap=64, seed=3)
        lat = np.asarray(r.mean_latency, dtype=float)
        se = max(lat.std(ddof=1) / math.sqrt(n_lad),
                 0.003 * ex.mean_latency)
        z = (lat.mean() - ex.mean_latency) / se
        assert abs(z) < 3.0, (disc, float(lat.mean()), ex.mean_latency,
                              float(z))
        av = np.asarray(r.availability, dtype=float).mean()
        assert abs(av - ex.availability) < 0.01, (disc, av,
                                                  ex.availability)

    def test_mtbf_inf_converges_to_base(self):
        base = markov.solve(self.LAM, MODEL, b_max=SW_BMAX)
        far = markov.solve(self.LAM, MODEL, b_max=SW_BMAX, mtbf=1e9,
                           mttr=0.5)
        assert math.isclose(far.mean_latency, base.mean_latency,
                            rel_tol=1e-4)
        assert far.availability > 1.0 - 1e-6

    def test_mtbf_none_is_exactly_base(self):
        a = markov.solve(self.LAM, MODEL, b_max=SW_BMAX)
        b = markov.solve(self.LAM, MODEL, b_max=SW_BMAX, mtbf=None)
        assert a.mean_latency == b.mean_latency
        assert np.array_equal(a.pi, b.pi)

    def test_completion_moments_reduce(self):
        s = 1.4
        ec, ec2 = markov.completion_moments(s, 0.0, 0.0)
        assert (ec, ec2) == (s, s * s)
        ec, _ = markov.completion_moments(s, 8.0, 0.5)
        assert math.isclose(ec, s * (1.0 + 0.5 / 8.0))
        ec, _ = markov.completion_moments(s, 8.0, 0.5, restart=True)
        xi = 1.0 / 8.0
        assert math.isclose(ec, (1.0 / xi + 0.5) * math.expm1(xi * s))


class TestQueueCapacityHeadroom:
    """S1: capacity sizing from the completion-time law keeps the
    hard-buffer witness (buffer_dropped == 0) at MTTR up to
    10·τ[b_max]."""

    @pytest.mark.parametrize("disc", ["resume", "restart"])
    def test_no_buffer_drops_at_long_mttr(self, disc):
        lam, b_max = 2.0, SW_BMAX
        tau_top = MODEL.alpha * b_max + MODEL.tau0        # τ[b_max]
        mttr = 10.0 * tau_top
        mtbf = 60.0
        q_cap = engine.queue_capacity(
            np.array([lam]), MODEL.alpha, MODEL.tau0, b_max,
            mtbf=np.array([mtbf]), mttr=np.array([mttr]),
            restart=np.array([disc == "restart"]))
        g = SweepGrid.from_points([lam] * 4, MODEL.alpha, MODEL.tau0,
                                  b_max=b_max, fail_disc=disc,
                                  mtbf=mtbf, mttr=mttr)
        r = sweep(g, n_batches=4000, q_cap=q_cap, a_cap=q_cap, seed=2)
        assert int(r.buffer_dropped.sum()) == 0
        assert np.all(np.asarray(r.n_failures) > 0)

    def test_inflation_monotone_in_mttr(self):
        lam = np.array([2.0])
        lo = engine.completion_inflation(lam, MODEL.alpha, MODEL.tau0,
                                         SW_BMAX, 60.0, 1.0)
        hi = engine.completion_inflation(lam, MODEL.alpha, MODEL.tau0,
                                         SW_BMAX, 60.0, 14.0)
        assert np.all(hi > lo) and np.all(lo >= 1.0)
        rst = engine.completion_inflation(
            lam, MODEL.alpha, MODEL.tau0, SW_BMAX, 2.0, 1.0,
            restart=np.array([True]))
        res = engine.completion_inflation(
            lam, MODEL.alpha, MODEL.tau0, SW_BMAX, 2.0, 1.0,
            restart=np.array([False]))
        assert np.all(rst > res)      # re-execution dominates


class TestRhoEffDiagnostic:
    """S6: the chain refuses unstable failure regimes with an
    actionable message, not an opaque recurrence error."""

    def test_names_rho_eff_and_repair_pair(self):
        with pytest.raises(ValueError) as ei:
            markov.solve(6.0, MODEL, b_max=SW_BMAX, mtbf=1.0, mttr=2.0,
                         fail_disc="restart")
        msg = str(ei.value)
        assert "rho_eff" in msg
        assert "MTBF=1" in msg and "MTTR=2" in msg
        assert "restart" in msg

    def test_drop_needs_mc_reference(self):
        with pytest.raises(ValueError, match="drop"):
            markov.solve(2.0, MODEL, b_max=SW_BMAX, mtbf=8.0, mttr=0.5,
                         fail_disc="drop")

    def test_failure_chain_needs_finite_b_max(self):
        with pytest.raises(ValueError, match="b_max"):
            markov.solve(2.0, MODEL, mtbf=8.0, mttr=0.5)
