"""Statistical test harness for the k-replica fleet kernel.

The fleet kernel (``repro.core.sweep.fleet_sweep``) is pinned against
three independent references:

- the exact truncated Markov chain (k = 1 must reduce to the
  single-server model for every routing; a random split at (λ, k) must
  match the single queue at λ/k — Poisson thinning),
- the single-server sweep kernel (same policies, independent code path),
- the legacy per-event NumPy JSQ loop (``simulate_jsq_numpy``) on a
  shared seed ladder, within 3σ of the paired Monte Carlo error.

Plus bitwise determinism: a grid dispatched in one vmap batch must equal
the same grid sharded into two dispatches (``take`` + ``key_offset``) —
this guards the per-point ``fold_in`` key construction against
shape-dependent key consumption.

Most fleet points share ONE module-scoped dispatch (and one kernel
compile); keep any new points inside that grid if possible.
"""
import math

import numpy as np
import pytest

from repro.core.analytic import LinearServiceModel
from repro.core.evaluate import evaluate
from repro.core.grid import ROUTE_CODE, FleetGrid, SweepGrid
from repro.core.markov import solve
from repro.core.replicas import simulate_jsq, simulate_jsq_numpy
from repro.core.sweep import fleet_sweep, sweep

V100 = LinearServiceModel(alpha=0.1438, tau0=1.8874)
ALPHA, TAU0 = V100.alpha, V100.tau0

# one shared dispatch: all points use this kernel configuration
KW = dict(n_steps=4992, q_cap=128, a_cap=32, seed=7)
RHO = 0.5
LAM1 = RHO / ALPHA                 # single-replica rate at rho = 0.5
N_JSQ_REPS = 6                     # seed-ladder width (fleet side)


def _grid():
    """k=1 parity (3 routings) + k=4 random/rr + a k=1 timeout point
    + the k=4 JSQ seed ladder, all in one FleetGrid."""
    lam = [LAM1] * 3 + [4 * LAM1] * 2 + [LAM1] \
        + [4 * LAM1] * N_JSQ_REPS
    k = [1, 1, 1, 4, 4, 1] + [4] * N_JSQ_REPS
    routing = (["random", "round_robin", "jsq", "random", "round_robin",
                "random"] + ["jsq"] * N_JSQ_REPS)
    wait_max = [0.0] * 5 + [5.0] + [0.0] * N_JSQ_REPS
    wait_target = [0] * 5 + [32] + [0] * N_JSQ_REPS
    b_max = [0] * 5 + [64] + [0] * N_JSQ_REPS
    return FleetGrid.from_points(lam, ALPHA, TAU0, k=k, routing=routing,
                                 b_max=b_max, wait_max=wait_max,
                                 wait_target=wait_target)


@pytest.fixture(scope="module")
def fleet():
    grid = _grid()
    return grid, fleet_sweep(grid, **KW)


class TestParity:
    def test_no_drops(self, fleet):
        _, r = fleet
        assert int(r.buffer_dropped.sum()) == 0

    def test_k1_matches_markov_all_routings(self, fleet):
        """k = 1 reduces to the single-server queue whatever the
        routing code says."""
        _, r = fleet
        m = solve(LAM1, V100)
        for i in range(3):
            assert r.mean_latency[i] == pytest.approx(m.mean_latency,
                                                      rel=0.04)
            assert r.mean_batch[i] == pytest.approx(m.mean_batch,
                                                    rel=0.05)
            assert r.utilization[i] == pytest.approx(m.utilization,
                                                     abs=0.02)

    def test_k1_matches_single_server_sweep(self, fleet):
        """Independent kernels, same model: fleet k=1 vs sweep."""
        _, r = fleet
        g1 = SweepGrid.from_points([LAM1], ALPHA, TAU0)
        s = sweep(g1, n_batches=4000, seed=5)
        assert r.mean_latency[0] == pytest.approx(s.mean_latency[0],
                                                  rel=0.04)
        assert r.latency_p50[0] == pytest.approx(s.latency_p50[0],
                                                 rel=0.06)
        assert r.latency_p99[0] == pytest.approx(s.latency_p99[0],
                                                 rel=0.10)

    def test_random_split_is_single_queue_at_lam_over_k(self, fleet):
        """Poisson thinning: a random 1/k split of Poisson(λ) feeds each
        replica an independent Poisson(λ/k) — the fleet's mean latency
        equals the exact single-queue solve at λ/k."""
        grid, r = fleet
        i = 3                                  # k=4, random, λ = 4·LAM1
        assert int(grid.k[i]) == 4
        m = solve(LAM1, V100)                  # λ/k = LAM1
        assert r.mean_latency[i] == pytest.approx(m.mean_latency,
                                                  rel=0.04)
        assert r.mean_batch[i] == pytest.approx(m.mean_batch, rel=0.05)

    def test_timeout_k1_matches_single_server_sweep(self, fleet):
        """The timeout policy runs through a different fleet code path
        (scheduled releases); pin it to the single-server timeout
        kernel."""
        _, r = fleet
        g = SweepGrid.from_points([LAM1], ALPHA, TAU0, b_max=[64],
                                  wait_max=[5.0], wait_target=[32])
        s = sweep(g, n_batches=4000, seed=5)
        assert r.mean_latency[5] == pytest.approx(s.mean_latency[0],
                                                  rel=0.05)
        assert r.mean_batch[5] == pytest.approx(s.mean_batch[0],
                                                rel=0.06)

    def test_jsq_matches_legacy_numpy_seed_ladder(self, fleet):
        """Fleet JSQ vs the per-event NumPy loop: mean latency within 3σ
        of the paired MC error over the seed ladders."""
        _, r = fleet
        fl = r.mean_latency[6:6 + N_JSQ_REPS]
        legacy = np.array([simulate_jsq_numpy(4 * LAM1, V100, 4,
                                              n_jobs=40_000, seed=s)
                           for s in range(3)])
        se = math.sqrt(fl.var(ddof=1) / len(fl)
                       + legacy.var(ddof=1) / len(legacy))
        se = max(se, 0.01 * legacy.mean())     # floor: 1% of the mean
        assert abs(fl.mean() - legacy.mean()) < 3.0 * se


class TestFleetSchema:
    def test_point_and_balance(self, fleet):
        grid, r = fleet
        p = r.point(3)
        assert p.backend == "fleet" and p.k == 4 and p.routing == "random"
        p.check()
        # measured jobs are attributed to replicas exactly once
        for i in range(len(grid)):
            assert int(r.jobs_by_replica[i].sum()) == int(r.n_jobs[i])
        # round-robin spreads batches near-uniformly at k=4
        bal = r.balance(4)
        assert bal.shape == (4,)
        assert np.all(np.abs(bal - 0.25) < 0.05)

    def test_rho_is_per_replica(self):
        g = FleetGrid.from_points([4.0], 0.1, 1.0, k=4)
        assert g.rho[0] == pytest.approx(0.1)
        assert g.routing_names == ["jsq"]

    def test_grid_construction_scales(self):
        g = FleetGrid.from_rhos([0.2, 0.5, 0.8], 0.1, 1.0,
                                ks=list(range(1, 17)),
                                routings=("random", "round_robin",
                                          "jsq"))
        assert len(g) == 3 * 16 * 3
        gp = FleetGrid.from_product([1.0, 2.0], [0.1], [1.0],
                                    ks=(1, 2, 4), routings=("jsq",))
        assert len(gp) == 6
        assert len(g.concat(g)) == 2 * len(g)
        assert len(g.take(slice(0, 10))) == 10

    def test_validation(self):
        g = SweepGrid.from_points([1.0], [0.1], [1.0])
        with pytest.raises(TypeError):
            fleet_sweep(g)
        gf = FleetGrid.from_points([1.0], 0.1, 1.0, k=2)
        with pytest.raises(ValueError):
            fleet_sweep(gf, q_cap=64, a_cap=32,
                        n_steps=64, warmup=64)
        with pytest.raises(TypeError):
            g.concat(gf)


class TestEvaluateFleetBackend:
    def test_fleet_backend_and_promotion(self, fleet):
        grid, r = fleet
        # promotion: a plain SweepGrid becomes a k=1 fleet
        g1 = SweepGrid.from_points([LAM1], ALPHA, TAU0)
        (res,) = evaluate(g1, backend="fleet", **KW)
        assert res.backend == "fleet" and res.k == 1
        m = solve(LAM1, V100)
        assert res.mean_latency == pytest.approx(m.mean_latency,
                                                 rel=0.04)

    def test_sweep_backend_rejects_fleet_grid(self):
        gf = FleetGrid.from_points([1.0], 0.1, 1.0, k=2)
        with pytest.raises(ValueError):
            evaluate(gf, backend="sweep")

    def test_single_server_backends_reject_multi_replica_grid(self):
        """A k>1 FleetGrid on a single-server backend would silently
        treat lam as one queue's rate — must raise instead."""
        gf = FleetGrid.from_points([1.0], 0.1, 1.0, k=4)
        for backend in ("analytic", "markov", "sim"):
            with pytest.raises(ValueError):
                evaluate(gf, backend=backend)

    def test_simulate_jsq_fleet_backend(self):
        """The re-implemented simulate_jsq agrees with the exact single
        queue at k=1 (where JSQ is vacuous)."""
        ew = simulate_jsq(LAM1, V100, 1, n_jobs=40_000, seed=2)
        m = solve(LAM1, V100)
        assert ew == pytest.approx(m.mean_latency, rel=0.05)
        with pytest.raises(ValueError):
            simulate_jsq(LAM1, V100, 2, backend="nope")


class TestDeterminism:
    """Same grid + seed ⇒ bitwise-identical results whether dispatched
    as one vmap batch or sharded into two (guards the fold_in key
    construction against shape-dependent key consumption)."""

    def test_sweep_split_dispatch_bitwise(self):
        g = SweepGrid.from_product([1.0, 2.0, 3.0], [0.1438],
                                   [0.75, 1.8874])
        full = sweep(g, n_batches=512, q_cap=256, seed=11)
        a = sweep(g.take(slice(0, 2)), n_batches=512, q_cap=256, seed=11)
        b = sweep(g.take(slice(2, None)), n_batches=512, q_cap=256,
                  seed=11, key_offset=2)
        for field in ("mean_latency", "mean_batch", "utilization"):
            merged = np.concatenate([getattr(a, field),
                                     getattr(b, field)])
            assert np.array_equal(getattr(full, field), merged), field
        assert np.array_equal(full.hist,
                              np.concatenate([a.hist, b.hist]))

    def test_fleet_split_dispatch_bitwise(self):
        g = FleetGrid.from_points([1.0, 2.0, 2.0, 3.0], 0.1438, 1.8874,
                                  k=[4, 4, 2, 4],
                                  routing=["jsq", "random",
                                           "round_robin", "jsq"])
        kw = dict(n_steps=512, q_cap=64, a_cap=16)
        full = fleet_sweep(g, seed=13, **kw)
        a = fleet_sweep(g.take(slice(0, 2)), seed=13, **kw)
        b = fleet_sweep(g.take(slice(2, None)), seed=13, key_offset=2,
                        **kw)
        for field in ("mean_latency", "mean_batch", "n_jobs"):
            merged = np.concatenate([getattr(a, field),
                                     getattr(b, field)])
            assert np.array_equal(getattr(full, field), merged), field
        assert np.array_equal(full.jobs_by_replica[:, :2],
                              np.concatenate([a.jobs_by_replica,
                                              b.jobs_by_replica])[:, :2])
