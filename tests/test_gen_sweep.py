"""Statistical test harness for the token-level generate kernel.

The gen kernel (``repro.core.gen_sweep.gen_sweep``) is pinned against
three independent references:

- the scalar numpy loops (``simulate_continuous_numpy`` /
  ``simulate_static_generate_numpy``) on a shared seed ladder, within
  3σ of the paired Monte Carlo error, for BOTH disciplines;
- the exact truncated Markov chain and the scalar request-level
  simulator: the static discipline is the paper's batch queue at the
  equivalent request-level law α' = prompt·α_p + gen·α_d,
  τ0' = τ0_p + gen·τ0_d (see docs/theory.md §"Token-level service
  law"), so its mean must match ``markov.solve`` at (α', τ0', b_max);
- the ``max_active = 1`` degenerate case, where both disciplines
  collapse to the same single-slot queue — bitwise-identically, since
  the admission gate is the only code path that differs.

Plus the split-dispatch determinism contract pinned by the sweep/fleet
kernels: a grid dispatched in one vmap batch must equal the same grid
sharded into two dispatches (``take`` + ``key_offset``) bitwise.

Most points share ONE module-scoped dispatch (and one kernel compile);
keep any new points inside that grid if possible.
"""
import math

import numpy as np
import pytest

from repro.core.analytic import LinearServiceModel
from repro.core.continuous_sim import (GenServiceModel,
                                       simulate_continuous,
                                       simulate_continuous_numpy,
                                       simulate_static_generate_numpy)
from repro.core.evaluate import evaluate
from repro.core.gen_sweep import gen_sweep
from repro.core.grid import DISC_CODE, FleetGrid, GenGrid, SweepGrid
from repro.core.markov import solve
from repro.core.simulate import simulate

MODEL = GenServiceModel(alpha_decode=0.14, tau0_decode=1.9,
                        alpha_prefill=0.035, tau0_prefill=1.9)
GEN, PROMPT, CAP = 32, 128, 64
ALPHA_EQ = PROMPT * MODEL.alpha_prefill + GEN * MODEL.alpha_decode
TAU0_EQ = MODEL.tau0_prefill + GEN * MODEL.tau0_decode
LAM = 0.5 / ALPHA_EQ              # decode-capacity-normalized rho = 0.5
N_REPS = 5                        # seed-ladder width (kernel side)

# one shared dispatch: all module points use this kernel configuration
# caps pinned explicitly: split-dispatch bitwise parity needs the
# sub-dispatches to share compiled shapes (adaptive defaults size
# q_cap/a_cap from the dispatched grid, which differs per subset)
KW = dict(n_steps=8192, q_cap=256, a_cap=64, seed=11)


def _grid():
    """Continuous + static rho=0.5 seed ladders, a low-load continuous
    point, and a mid-load static point, all in one GenGrid."""
    lam = [LAM] * (2 * N_REPS) + [0.1 / ALPHA_EQ, 0.6 / ALPHA_EQ]
    disc = (["continuous"] * N_REPS + ["static"] * N_REPS
            + ["continuous", "static"])
    return GenGrid.from_points(
        lam, MODEL.alpha_decode, MODEL.tau0_decode, MODEL.alpha_prefill,
        MODEL.tau0_prefill, prompt_len=PROMPT, gen_tokens=GEN,
        max_active=CAP, discipline=disc)


@pytest.fixture(scope="module")
def gen():
    grid = _grid()
    return grid, gen_sweep(grid, **KW)


def _ladder_se(kernel_vals, ref_vals, floor_frac=0.015):
    se = math.sqrt(kernel_vals.var(ddof=1) / len(kernel_vals)
                   + np.var(ref_vals, ddof=1) / len(ref_vals))
    return max(se, floor_frac * float(np.mean(ref_vals)))


class TestNumpyParity:
    def test_no_drops(self, gen):
        _, r = gen
        assert int(r.buffer_dropped.sum()) == 0

    def test_continuous_matches_numpy_seed_ladder(self, gen):
        _, r = gen
        k = r.mean_latency[:N_REPS]
        ref = np.array([simulate_continuous_numpy(
            LAM, MODEL, prompt_len=PROMPT, gen_tokens=GEN,
            max_active=CAP, n_jobs=12_000, seed=s).mean_latency
            for s in range(3)])
        se = _ladder_se(k, ref)
        assert abs(k.mean() - ref.mean()) < 3.0 * se

    def test_static_matches_numpy_seed_ladder(self, gen):
        _, r = gen
        k = r.mean_latency[N_REPS:2 * N_REPS]
        ref = np.array([simulate_static_generate_numpy(
            LAM, MODEL, prompt_len=PROMPT, gen_tokens=GEN, b_max=CAP,
            n_jobs=12_000, seed=s).mean_latency for s in range(3)])
        se = _ladder_se(k, ref)
        assert abs(k.mean() - ref.mean()) < 3.0 * se

    def test_utilization_parity_tight(self, gen):
        """The numpy references' exact interval-by-interval busy/span
        accounting (post-warmup window) matches the kernel's convention,
        so utilization agrees tightly, per discipline."""
        _, r = gen
        for lo, fn, kw in (
                (0, simulate_continuous_numpy, dict(max_active=CAP)),
                (N_REPS, simulate_static_generate_numpy,
                 dict(b_max=CAP))):
            k = r.utilization[lo:lo + N_REPS].mean()
            ref = np.mean([fn(LAM, MODEL, prompt_len=PROMPT,
                              gen_tokens=GEN, n_jobs=12_000, seed=s,
                              **kw).utilization for s in range(2)])
            assert abs(k - ref) < 0.015

    def test_mean_active_matches_numpy(self, gen):
        _, r = gen
        k = r.mean_batch[:N_REPS].mean()
        ref = np.mean([simulate_continuous_numpy(
            LAM, MODEL, prompt_len=PROMPT, gen_tokens=GEN,
            max_active=CAP, n_jobs=12_000, seed=s).mean_batch
            for s in range(2)])
        assert k == pytest.approx(ref, rel=0.08)


class TestExactReferences:
    """The static discipline IS the paper's batch queue at the
    equivalent request-level linear law — pin it to the exact chain and
    to the independent scalar simulator."""

    def test_equivalent_law_fields(self):
        g = _grid()
        assert g.equivalent_alpha[0] == pytest.approx(ALPHA_EQ)
        assert g.equivalent_tau0[0] == pytest.approx(TAU0_EQ)
        assert g.rho[0] == pytest.approx(0.5, rel=1e-5)

    def test_static_matches_markov_exact(self, gen):
        _, r = gen
        m = solve(LAM, LinearServiceModel(ALPHA_EQ, TAU0_EQ), b_max=CAP)
        k = r.mean_latency[N_REPS:2 * N_REPS]
        assert k.mean() == pytest.approx(m.mean_latency, rel=0.04)
        assert r.mean_batch[N_REPS:2 * N_REPS].mean() == pytest.approx(
            m.mean_batch, rel=0.05)
        assert r.utilization[N_REPS:2 * N_REPS].mean() == pytest.approx(
            m.utilization, abs=0.02)

    def test_static_matches_scalar_simulate(self, gen):
        _, r = gen
        k = r.mean_latency[N_REPS:2 * N_REPS]
        ref = np.array([simulate(
            LAM, LinearServiceModel(ALPHA_EQ, TAU0_EQ), b_max=CAP,
            n_jobs=25_000, seed=s).mean_latency for s in range(3)])
        se = _ladder_se(k, ref)
        assert abs(k.mean() - ref.mean()) < 3.0 * se

    def test_midload_static_matches_markov(self, gen):
        grid, r = gen
        i = 2 * N_REPS + 1
        m = solve(float(grid.lam[i]),
                  LinearServiceModel(ALPHA_EQ, TAU0_EQ), b_max=CAP)
        assert r.mean_latency[i] == pytest.approx(m.mean_latency,
                                                  rel=0.06)

    def test_low_load_latency_floor(self, gen):
        """A lightly loaded continuous server's E[W] sits at the solo
        service floor prefill(prompt) + gen·decode(1)."""
        _, r = gen
        floor = MODEL.prefill(PROMPT) + GEN * MODEL.decode_step(1)
        i = 2 * N_REPS
        assert floor * 0.9 <= r.mean_latency[i] <= floor * 1.6

    def test_max_active_one_disciplines_identical(self):
        """With one slot the admission gate is the only code-path
        difference between the disciplines — same seed, same point
        index ⇒ bitwise-identical trajectories."""
        lam1 = 0.4 / (ALPHA_EQ + TAU0_EQ)
        res = {}
        for disc in ("static", "continuous"):
            g = GenGrid.from_points(
                [lam1], MODEL.alpha_decode, MODEL.tau0_decode,
                MODEL.alpha_prefill, MODEL.tau0_prefill,
                prompt_len=PROMPT, gen_tokens=GEN, max_active=1,
                discipline=disc)
            res[disc] = gen_sweep(g, n_steps=4096, q_cap=128, seed=3)
        for field in ("mean_latency", "mean_batch", "utilization",
                      "n_jobs"):
            assert np.array_equal(getattr(res["static"], field),
                                  getattr(res["continuous"], field)), \
                field
        m = solve(lam1, LinearServiceModel(ALPHA_EQ, TAU0_EQ), b_max=1)
        assert res["static"].mean_latency[0] == pytest.approx(
            m.mean_latency, rel=0.05)


class TestDeterminism:
    def test_split_dispatch_bitwise(self):
        """Same grid + seed ⇒ bitwise-identical results whether
        dispatched as one vmap batch or sharded into two (guards the
        fold_in key construction against shape-dependent key
        consumption)."""
        g = GenGrid.from_points(
            [LAM, 0.8 * LAM, LAM, 0.6 * LAM], MODEL.alpha_decode,
            MODEL.tau0_decode, MODEL.alpha_prefill, MODEL.tau0_prefill,
            prompt_len=PROMPT, gen_tokens=[8, 16, 8, 32],
            max_active=[16, 32, 16, 8],
            discipline=["continuous", "static", "static", "continuous"])
        kw = dict(n_steps=2048, q_cap=64, a_cap=64)
        full = gen_sweep(g, seed=13, **kw)
        a = gen_sweep(g.take(slice(0, 2)), seed=13, **kw)
        b = gen_sweep(g.take(slice(2, None)), seed=13, key_offset=2,
                      **kw)
        for field in ("mean_latency", "mean_batch", "utilization",
                      "n_jobs"):
            merged = np.concatenate([getattr(a, field),
                                     getattr(b, field)])
            assert np.array_equal(getattr(full, field), merged), field
        assert np.array_equal(full.hist,
                              np.concatenate([a.hist, b.hist]))


class TestGridAndSchema:
    def test_point_schema(self, gen):
        _, r = gen
        p = r.point(0)
        assert p.backend == "gen" and p.discipline == "continuous"
        p.check()
        assert r.point(N_REPS).discipline == "static"

    def test_grid_construction(self):
        g = GenGrid.from_product(
            [0.05, 0.1], MODEL, prompt_lens=(64, 128),
            gen_tokens=(8, 32), max_actives=(16, 64),
            disciplines=("static", "continuous"))
        assert len(g) == 2 * 2 * 2 * 2 * 2
        assert set(np.unique(g.discipline)) == set(DISC_CODE.values())
        gr = GenGrid.from_rhos([0.2, 0.5, 0.8], MODEL,
                               gen_tokens=(8, 32),
                               disciplines=("static", "continuous"))
        assert len(gr) == 3 * 2 * 2
        assert np.allclose(gr.rho, np.repeat([0.2, 0.5, 0.8], 4),
                           rtol=1e-5)
        assert len(gr.concat(gr)) == 2 * len(gr)
        assert len(gr.take(slice(0, 5))) == 5

    def test_validation(self):
        sg = SweepGrid.from_points([1.0], [0.1], [1.0])
        with pytest.raises(TypeError):
            gen_sweep(sg)
        g1 = GenGrid.from_points([0.05], MODEL.alpha_decode,
                                 MODEL.tau0_decode, MODEL.alpha_prefill,
                                 MODEL.tau0_prefill, max_active=512)
        with pytest.raises(ValueError):
            gen_sweep(g1, q_cap=256)       # max_active > q_cap
        with pytest.raises(ValueError):
            GenGrid.from_points([0.05], 0.1, 1.0, 0.1, 1.0,
                                max_active=0)
        with pytest.raises(KeyError):
            GenGrid.from_points([0.05], 0.1, 1.0, 0.1, 1.0,
                                discipline="nope")

    def test_evaluate_gen_backend(self, gen):
        grid, r = gen
        res = evaluate(grid.take(slice(0, 2)), backend="gen", **KW)
        assert [x.backend for x in res] == ["gen", "gen"]
        # evaluate() runs the same kernel+keys: bitwise-equal points
        assert res[0].mean_latency == r.point(0).mean_latency
        assert res[0].discipline == "continuous"

    def test_evaluate_guards(self):
        g = GenGrid.from_points([0.05], 0.1, 1.0, 0.1, 1.0)
        for backend in ("analytic", "markov", "sim", "sweep", "fleet"):
            with pytest.raises(ValueError):
                evaluate(g, backend=backend)
        sg = SweepGrid.from_points([1.0], [0.1], [1.0])
        with pytest.raises(ValueError):
            evaluate(sg, backend="gen")
        fg = FleetGrid.from_points([1.0], 0.1, 1.0, k=2)
        with pytest.raises(ValueError):
            evaluate(fg, backend="gen")

    def test_simulate_continuous_gen_backend(self):
        """The wrapper dispatches one point through the kernel and maps
        n_jobs to an equivalent step count."""
        r = simulate_continuous(LAM, MODEL, prompt_len=PROMPT,
                                gen_tokens=GEN, max_active=CAP,
                                n_jobs=600, seed=1, backend="gen")
        assert r.backend == "gen" and r.discipline == "continuous"
        assert r.mean_latency > 0 and r.n_jobs > 100
        with pytest.raises(ValueError):
            simulate_continuous(LAM, MODEL, backend="nope")
