"""Percentile-reconstruction edge cases (repro.core.hist): saturated
top bin, empty histograms, single-sample grids, and the streaming
sketch's pinned relative-error contract against exact sample
percentiles.
"""
import numpy as np
import pytest

from repro.core import hist as h


def _host_bins(samples: np.ndarray, sketch: bool,
               n_bins: int) -> np.ndarray:
    """NumPy mirror of the device-side bit binning."""
    shift, base, _ = h.bin_params(sketch)
    bits = samples.astype(np.float32).view(np.int32)
    return np.clip((bits >> shift) - base, 0, n_bins - 1)


class TestEdgeCases:
    def test_empty_histogram_is_nan(self):
        hist = np.zeros((3, 512), dtype=np.int64)
        hist[1, 40] = 10                      # middle row has mass
        p50, p99 = h.hist_percentiles(hist, (50, 99))
        assert np.isnan(p50[0]) and np.isnan(p99[2])
        assert np.isfinite(p50[1]) and np.isfinite(p99[1])

    def test_single_sample_grid(self):
        """One job in one bin: every percentile lands inside that
        bin — never NaN, never outside the bin's edges."""
        hist = np.zeros((1, 512), dtype=np.int64)
        j = 123
        hist[0, j] = 1
        edges = h.hist_edges(512)
        for p in (h.hist_percentiles(hist, (1, 50, 99.9))):
            assert edges[j] <= p[0] <= edges[j + 1]

    def test_saturated_top_bin(self):
        """Out-of-range latencies clip into the last bin; percentiles
        stay finite and bounded by the top edge."""
        edges = h.hist_edges(512)
        huge = np.array([edges[-1] * 10, np.float32(np.inf)],
                        dtype=np.float32)
        bins = _host_bins(huge, sketch=False, n_bins=512)
        assert np.all(bins == 511)
        hist = np.zeros((1, 512), dtype=np.int64)
        hist[0, 511] = 1000
        (p99,) = h.hist_percentiles(hist, (99,))
        assert edges[511] <= p99[0] <= edges[512]
        assert np.isfinite(p99[0])

    def test_bottom_clip(self):
        tiny = np.array([0.0, 2.0 ** -60], dtype=np.float32)
        assert np.all(_host_bins(tiny, False, 512) == 0)
        assert np.all(_host_bins(tiny, True, h.SKETCH_BINS) == 0)

    def test_device_bins_match_host(self):
        import jax

        rng = np.random.default_rng(2)
        lats = rng.lognormal(0.0, 3.0, 4096).astype(np.float32)
        for sketch, n_bins in ((False, 512), (True, h.SKETCH_BINS)):
            dev = jax.jit(lambda x, s=sketch, n=n_bins:
                          h.bit_bins(x, n, s))(lats)
            assert np.array_equal(np.asarray(dev),
                                  _host_bins(lats, sketch, n_bins))

    def test_edges_are_monotone_and_bins_nest(self):
        for edges in (h.hist_edges(512), h.sketch_edges()):
            assert np.all(np.diff(edges) > 0)
        # samples binned at bin j really lie within [edge[j], edge[j+1])
        rng = np.random.default_rng(3)
        lats = rng.lognormal(1.0, 1.0, 2048).astype(np.float32)
        edges = h.sketch_edges()
        bins = _host_bins(lats, True, h.SKETCH_BINS)
        lo, hi = edges[bins], edges[bins + 1]
        assert np.all((lats.astype(np.float64) >= lo)
                      & (lats.astype(np.float64) < hi))


class TestSketchErrorContract:
    """S5: sketch percentiles within SKETCH_REL_ERR of the exact
    sample percentile — the DDSketch-style pinned bound."""

    def test_relative_error_bound(self):
        rng = np.random.default_rng(7)
        qs = (50, 95, 99)
        for scale in (0.5, 2.0):
            lats = rng.lognormal(scale, 1.2, 20_000).astype(np.float32)
            counts = np.zeros((1, h.SKETCH_BINS), dtype=np.int64)
            np.add.at(counts[0], _host_bins(lats, True, h.SKETCH_BINS),
                      1)
            est = h.sketch_percentiles(counts, qs)
            exact = np.percentile(lats.astype(np.float64), qs)
            for e, x in zip(est, exact):
                assert abs(e[0] - x) / x <= h.SKETCH_REL_ERR, (e, x)

    def test_rel_err_constant_is_widest_bin(self):
        """Bins are linear within an octave, so widths alternate; the
        pinned constant is exactly the widest (first-of-octave) bin."""
        edges = h.sketch_edges()
        widths = edges[1:] / edges[:-1] - 1.0
        assert np.max(widths) == pytest.approx(h.SKETCH_REL_ERR,
                                               rel=1e-9)
        assert np.all(widths <= h.SKETCH_REL_ERR * (1 + 1e-9))

    def test_sketch_percentiles_rejects_wrong_width(self):
        with pytest.raises(ValueError, match="bins"):
            h.sketch_percentiles(np.zeros((1, 512), dtype=np.int64),
                                 (50,))

    def test_full_hist_beats_sketch_resolution(self):
        """The 512-bin full histogram's per-bin relative width is
        finer than the sketch's (the memory/accuracy trade the sketch
        makes explicit)."""
        full = h.hist_edges(512)
        full_w = full[100:-1] / full[99:-2] - 1.0   # away from clip
        assert np.max(full_w) < h.SKETCH_REL_ERR
