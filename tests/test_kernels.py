"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,kv,hd", [
        (1, 128, 2, 2, 32),     # MHA
        (2, 256, 4, 2, 64),     # GQA 2:1
        (1, 256, 8, 1, 64),     # MQA
        (2, 128, 4, 4, 128),    # wide heads
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_sweep(self, b, s, h, kv, hd, dtype):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
        k = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
        v = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
        out = flash_attention(q, k, v, causal=True, bq=64, bk=64,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            **_tol(dtype))

    @pytest.mark.parametrize("window", [16, 64, 100])
    def test_sliding_window(self, window):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (2, 256, 4, 32))
        k = jax.random.normal(ks[1], (2, 256, 2, 32))
        v = jax.random.normal(ks[2], (2, 256, 2, 32))
        out = flash_attention(q, k, v, causal=True, window=window,
                              bq=64, bk=64, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_non_causal(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 32))
        k = jax.random.normal(ks[1], (1, 128, 2, 32))
        v = jax.random.normal(ks[2], (1, 128, 2, 32))
        out = flash_attention(q, k, v, causal=False, bq=64, bk=64,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("bq,bk", [(32, 32), (64, 128), (128, 64)])
    def test_block_shape_invariance(self, bq, bk):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 256, 2, 32))
        k = jax.random.normal(ks[1], (1, 256, 2, 32))
        v = jax.random.normal(ks[2], (1, 256, 2, 32))
        out = flash_attention(q, k, v, causal=True, bq=bq, bk=bk,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


class TestDecodeAttention:
    @pytest.mark.parametrize("b,s,h,kv,hd", [
        (2, 256, 4, 2, 64),
        (1, 512, 8, 8, 32),
        (4, 128, 8, 2, 128),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, b, s, h, kv, hd, dtype):
        ks = jax.random.split(KEY, 4)
        q = jax.random.normal(ks[0], (b, h, hd), dtype)
        k = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
        v = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
        lengths = jax.random.randint(ks[3], (b,), 1, s)
        out = decode_attention(q, k, v, lengths, bk=64, interpret=True)
        want = ref.decode_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            **_tol(dtype))

    def test_window_and_ragged_lengths(self):
        ks = jax.random.split(KEY, 3)
        b, s = 3, 256
        q = jax.random.normal(ks[0], (b, 4, 64))
        k = jax.random.normal(ks[1], (b, s, 2, 64))
        v = jax.random.normal(ks[2], (b, s, 2, 64))
        lengths = jnp.array([5, 100, 255], jnp.int32)
        out = decode_attention(q, k, v, lengths, window=32, bk=64,
                               interpret=True)
        want = ref.decode_attention_ref(q, k, v, lengths, window=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


class TestSSDScan:
    @pytest.mark.parametrize("b,s,nh,g,hd,ds", [
        (1, 128, 2, 1, 32, 16),
        (2, 256, 4, 2, 64, 16),
        (1, 256, 8, 1, 32, 64),    # mamba2-style big state
    ])
    def test_sweep(self, b, s, nh, g, hd, ds):
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (b, s, nh, hd)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
        a = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
        bm = jax.random.normal(ks[3], (b, s, g, ds)) * 0.3
        cm = jax.random.normal(ks[4], (b, s, g, ds)) * 0.3
        out = ssd_scan(x, dt, a, bm, cm, chunk=64, interpret=True)
        want = ref.ssd_scan_ref(x, dt, a, bm, cm)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("chunk", [32, 64, 128])
    def test_chunk_invariance(self, chunk):
        ks = jax.random.split(KEY, 5)
        b, s, nh, g, hd, ds = 1, 128, 2, 1, 32, 16
        x = jax.random.normal(ks[0], (b, s, nh, hd)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
        a = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
        bm = jax.random.normal(ks[3], (b, s, g, ds)) * 0.3
        cm = jax.random.normal(ks[4], (b, s, g, ds)) * 0.3
        out = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
        want = ref.ssd_scan_ref(x, dt, a, bm, cm)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)

    def test_matches_model_ssd(self):
        """The kernel agrees with the model's chunked jnp implementation."""
        from repro.configs.base import SSMConfig
        from repro.models.mamba2 import _ssd_chunked
        ks = jax.random.split(KEY, 5)
        b, s, nh, hd, ds = 1, 128, 2, 32, 16
        scfg = SSMConfig(d_state=ds, head_dim=hd, n_groups=1, chunk_size=32)
        x = jax.random.normal(ks[0], (b, s, nh, hd)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
        a = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
        bm = jax.random.normal(ks[3], (b, s, 1, ds)) * 0.3
        cm = jax.random.normal(ks[4], (b, s, 1, ds)) * 0.3
        y_model, _ = _ssd_chunked(x, dt, a, bm, cm, scfg)
        y_kernel = ssd_scan(x, dt, a, bm, cm, chunk=32, interpret=True)
        np.testing.assert_allclose(np.asarray(y_kernel),
                                   np.asarray(y_model),
                                   rtol=2e-3, atol=2e-3)
