"""Streaming metrics tap (repro.core.metrics): host-side aggregation
unit tests, the JSONL/Prometheus output contract, and the end-to-end
io_callback integration — a tapped dispatch must be bitwise identical
to the untapped one (the tap is observability, never physics).
"""
import json

import numpy as np
import pytest

from repro.core.analytic import LinearServiceModel
from repro.core.grid import SweepGrid
from repro.core.metrics import FIELDS, MetricsTap, tap_superstep
from repro.core.sweep import sweep

V100 = LinearServiceModel(alpha=0.1438, tau0=1.8874)

# every per-superstep JSONL record carries exactly these keys
SUPERSTEP_KEYS = {
    "type", "step", "lanes", "queue_depth_mean", "jobs_total",
    "occupancy", "dropped_total", "overflow_total", "abandoned_total",
    "wall_s", "jobs_per_sec", "label",
}


def _grid():
    return SweepGrid.from_product([1.0, 2.5], [V100.alpha],
                                  [V100.tau0], b_maxes=(8,))


class TestTapUnit:
    def test_aggregates_and_flushes_per_superstep(self, tmp_path):
        jsonl = tmp_path / "m.jsonl"
        with MetricsTap(jsonl, label="unit",
                        expected_points=2) as tap:
            for lane_jobs in (10, 30):
                tap._record(0, 4.0, lane_jobs, 1.0, 2.0, 0, 0, 0)
            for lane_jobs in (20, 60):
                tap._record(1, 6.0, lane_jobs, 3.0, 4.0, 1, 2, 3)
        recs = [json.loads(l) for l in jsonl.read_text().splitlines()]
        assert [r["step"] for r in recs] == [0, 1]
        r0, r1 = recs
        assert set(r0) == SUPERSTEP_KEYS
        assert r0["lanes"] == 2 and r0["jobs_total"] == 40
        assert r0["queue_depth_mean"] == pytest.approx(4.0)
        assert r0["occupancy"] == pytest.approx(0.5)
        assert r0["jobs_per_sec"] is None          # no prior flush
        assert r1["jobs_total"] == 80
        assert (r1["dropped_total"], r1["overflow_total"],
                r1["abandoned_total"]) == (2, 4, 6)
        assert r1["jobs_per_sec"] is None or r1["jobs_per_sec"] >= 0

    def test_close_flushes_stragglers_in_order(self, tmp_path):
        jsonl = tmp_path / "m.jsonl"
        tap = MetricsTap(jsonl, label="unit")    # no expected_points
        tap._record(2, 1.0, 5, 1.0, 1.0, 0, 0, 0)
        tap._record(0, 1.0, 1, 1.0, 1.0, 0, 0, 0)
        tap._record(1, 1.0, 3, 1.0, 1.0, 0, 0, 0)
        assert jsonl.read_text() == ""           # nothing until close
        tap.close()
        tap.close()                              # idempotent
        steps = [json.loads(l)["step"]
                 for l in jsonl.read_text().splitlines()]
        assert steps == [0, 1, 2]

    def test_prometheus_text_rewritten_atomically(self, tmp_path):
        prom = tmp_path / "m.prom"
        with MetricsTap(prom_path=prom, label="p",
                        expected_points=1) as tap:
            tap._record(0, 2.0, 7, 1.0, 2.0, 1, 0, 0)
            text = prom.read_text()
        assert 'repro_supersteps_total{label="p"} 1' in text
        assert 'repro_jobs_total{label="p"} 7' in text
        assert 'repro_dropped_total{label="p"} 1' in text
        for name in ("repro_queue_depth_mean", "repro_occupancy",
                     "repro_jobs_per_sec"):
            assert f'{name}{{label="p"}}' in text
        assert not list(tmp_path.glob("*.tmp"))  # no litter

    def test_observe_summary_nulls_nans(self, tmp_path):
        jsonl = tmp_path / "m.jsonl"
        with MetricsTap(jsonl, label="s") as tap:
            tap.observe_summary(kind="sweep", p50_median=float("nan"),
                                jobs_total=12)
        rec = json.loads(jsonl.read_text().splitlines()[0])
        assert rec["type"] == "summary" and rec["label"] == "s"
        assert rec["p50_median"] is None
        assert rec["jobs_total"] == 12

    def test_summary_snapshot(self):
        tap = MetricsTap(expected_points=1)
        tap._record(0, 1.0, 9, 1.0, 2.0, 0, 0, 0)
        s = tap.summary()
        assert s["supersteps"] == 1 and s["records"] == 1
        assert s["pending"] == 0 and s["jobs_total"] == 9

    def test_tap_superstep_none_is_noop(self):
        tap_superstep(None, 0, queue=1)          # must not import jax

    def test_fields_order_matches_record(self):
        assert FIELDS == ("queue", "jobs", "busy", "span", "dropped",
                          "overflow", "abandoned")


class TestTappedDispatch:
    """End to end through io_callback inside the jit sweep kernel."""

    @pytest.fixture(scope="class")
    def tapped(self, tmp_path_factory):
        d = tmp_path_factory.mktemp("metrics")
        g = _grid()
        kw = dict(n_batches=128, q_cap=64, seed=3, sketch=True)
        plain = sweep(g, **kw)
        with MetricsTap(d / "m.jsonl", d / "m.prom", label="e2e",
                        expected_points=len(g)) as tap:
            r = sweep(g, metrics_tap=tap, **kw)
        return g, plain, r, tap, d

    def test_tap_changes_nothing_bitwise(self, tapped):
        _, plain, r, _, _ = tapped
        for f in ("mean_latency", "n_jobs", "hist", "hist_sums",
                  "latency_p99"):
            assert np.array_equal(getattr(plain, f), getattr(r, f)), f

    def test_every_superstep_streamed(self, tapped):
        g, _, r, tap, d = tapped
        lines = (d / "m.jsonl").read_text().splitlines()
        recs = [json.loads(l) for l in lines]
        steps = [x for x in recs if x["type"] == "superstep"]
        # 128 batches / 32-step supersteps = 4 supersteps, all lanes
        assert [x["step"] for x in steps] == list(range(4))
        assert all(x["lanes"] == len(g) for x in steps)
        assert all(set(x) == set(steps[0]) for x in steps)
        assert tap.records == 4 * len(g)
        # cumulative job counter ends at the dispatch total (the
        # engine's measured jobs, post-warmup)
        assert steps[-1]["jobs_total"] == int(r.n_jobs.sum())
        assert all(b["jobs_total"] >= a["jobs_total"] for a, b
                   in zip(steps, steps[1:]))

    def test_summary_record_has_percentile_medians(self, tapped):
        _, _, _, _, d = tapped
        recs = [json.loads(l)
                for l in (d / "m.jsonl").read_text().splitlines()]
        summaries = [x for x in recs if x["type"] == "summary"]
        assert len(summaries) == 1
        s = summaries[0]
        assert s["kind"] == "sweep" and s["points"] == 2
        for k in ("p50_median", "p95_median", "p99_median"):
            assert k in s

    def test_prom_file_reflects_final_state(self, tapped):
        _, _, r, _, d = tapped
        text = (d / "m.prom").read_text()
        assert 'repro_supersteps_total{label="e2e"} 4' in text
        assert (f'repro_jobs_total{{label="e2e"}} '
                f'{int(r.n_jobs.sum())}') in text
