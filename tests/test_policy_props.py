"""Property-based tests for the batching policies (repro.core.policy).

These pin the contracts every consumer of ``BatchPolicy`` relies on —
the serving engine's event loop, the scalar simulator, and the sweep /
fleet kernels' (b_max, wait_max, wait_target) encodings:

- ``take(n)`` never exceeds the queue (or the cap) and is monotone in n,
- ``release_time`` never travels back in time,
- ``TimeoutBatch`` releases by ``oldest_arrival + max_wait`` at the
  latest (unless that deadline already passed), and immediately once
  ``target`` jobs wait.

Runs under real `hypothesis` when installed, else the deterministic
fallback sampler in tests/_hypothesis_compat.py.
"""
import pytest

from repro.core.policy import BatchAllWaiting, CappedBatch, TimeoutBatch

from _hypothesis_compat import given, settings, st

POLICIES = [BatchAllWaiting(), CappedBatch(cap=8), CappedBatch(cap=64),
            TimeoutBatch(max_wait=0.005, target=4, cap=16)]


@settings(max_examples=80, deadline=None)
@given(n=st.integers(min_value=0, max_value=10_000))
def test_take_never_exceeds_waiting_or_cap(n):
    for p in POLICIES:
        b = p.take(n)
        assert 0 <= b <= n
        assert b <= p.b_max


@settings(max_examples=80, deadline=None)
@given(n1=st.integers(min_value=0, max_value=10_000),
       n2=st.integers(min_value=0, max_value=10_000))
def test_take_monotone_in_queue_length(n1, n2):
    lo, hi = sorted((n1, n2))
    for p in POLICIES:
        assert p.take(lo) <= p.take(hi)


@settings(max_examples=80, deadline=None)
@given(now=st.floats(min_value=0.0, max_value=1e4),
       age=st.floats(min_value=0.0, max_value=1e3),
       n=st.integers(min_value=1, max_value=200))
def test_release_time_never_in_the_past(now, age, n):
    oldest = now - age
    for p in POLICIES:
        assert p.release_time(now, oldest, n) >= now


@settings(max_examples=120, deadline=None)
@given(now=st.floats(min_value=0.0, max_value=1e4),
       age=st.floats(min_value=0.0, max_value=1e3),
       n=st.integers(min_value=1, max_value=200),
       max_wait=st.floats(min_value=1e-6, max_value=10.0),
       target=st.integers(min_value=1, max_value=64))
def test_timeout_release_bounded_by_deadline(now, age, n, max_wait,
                                             target):
    """The release never exceeds oldest_arrival + max_wait — except when
    that deadline already passed, in which case it is exactly `now`."""
    p = TimeoutBatch(max_wait=max_wait, target=target, cap=64)
    oldest = now - age
    rel = p.release_time(now, oldest, n)
    deadline = oldest + max_wait
    if n >= target:
        assert rel == now
    elif deadline <= now:
        assert rel == now
    else:
        assert rel == deadline


def test_non_timeout_policies_release_immediately():
    for p in (BatchAllWaiting(), CappedBatch(cap=4)):
        assert p.release_time(3.5, 1.0, 7) == 3.5


def test_take_values_pin():
    assert BatchAllWaiting().take(17) == 17
    assert CappedBatch(cap=8).take(17) == 8
    assert TimeoutBatch(cap=8).take(17) == 8
