"""Property-based tests for the batching policies (repro.core.policy)
and the SLO/admission-control accounting laws.

These pin the contracts every consumer of ``BatchPolicy`` relies on —
the serving engine's event loop, the scalar simulator, and the sweep /
fleet kernels' (b_max, wait_max, wait_target) encodings:

- ``take(n)`` never exceeds the queue (or the cap) and is monotone in n,
- ``release_time`` never travels back in time,
- ``TimeoutBatch`` releases by ``oldest_arrival + max_wait`` at the
  latest (unless that deadline already passed), and immediately once
  ``target`` jobs wait.

The admission-control block drives ``repro.core.loss_ref`` (the
chronological numpy mirror of the kernels' loss semantics) over random
(λ, q_max, deadline, overflow, retry) points and asserts the laws that
must hold for EVERY loss configuration, not just the pinned ones in
test_backpressure.py:

- the four terminal classes partition the offered jobs exactly,
- goodput ≤ throughput ≤ λ (as rates, via the measured fractions),
- at a fixed seed, tightening only the deadline never increases the
  goodput fraction.

Runs under real `hypothesis` when installed, else the deterministic
fallback sampler in tests/_hypothesis_compat.py.
"""
import pytest

from repro.core.analytic import LinearServiceModel
from repro.core.loss_ref import simulate_loss_numpy
from repro.core.policy import BatchAllWaiting, CappedBatch, TimeoutBatch

from _hypothesis_compat import given, settings, st

POLICIES = [BatchAllWaiting(), CappedBatch(cap=8), CappedBatch(cap=64),
            TimeoutBatch(max_wait=0.005, target=4, cap=16)]


@settings(max_examples=80, deadline=None)
@given(n=st.integers(min_value=0, max_value=10_000))
def test_take_never_exceeds_waiting_or_cap(n):
    for p in POLICIES:
        b = p.take(n)
        assert 0 <= b <= n
        assert b <= p.b_max


@settings(max_examples=80, deadline=None)
@given(n1=st.integers(min_value=0, max_value=10_000),
       n2=st.integers(min_value=0, max_value=10_000))
def test_take_monotone_in_queue_length(n1, n2):
    lo, hi = sorted((n1, n2))
    for p in POLICIES:
        assert p.take(lo) <= p.take(hi)


@settings(max_examples=80, deadline=None)
@given(now=st.floats(min_value=0.0, max_value=1e4),
       age=st.floats(min_value=0.0, max_value=1e3),
       n=st.integers(min_value=1, max_value=200))
def test_release_time_never_in_the_past(now, age, n):
    oldest = now - age
    for p in POLICIES:
        assert p.release_time(now, oldest, n) >= now


@settings(max_examples=120, deadline=None)
@given(now=st.floats(min_value=0.0, max_value=1e4),
       age=st.floats(min_value=0.0, max_value=1e3),
       n=st.integers(min_value=1, max_value=200),
       max_wait=st.floats(min_value=1e-6, max_value=10.0),
       target=st.integers(min_value=1, max_value=64))
def test_timeout_release_bounded_by_deadline(now, age, n, max_wait,
                                             target):
    """The release never exceeds oldest_arrival + max_wait — except when
    that deadline already passed, in which case it is exactly `now`."""
    p = TimeoutBatch(max_wait=max_wait, target=target, cap=64)
    oldest = now - age
    rel = p.release_time(now, oldest, n)
    deadline = oldest + max_wait
    if n >= target:
        assert rel == now
    elif deadline <= now:
        assert rel == now
    else:
        assert rel == deadline


def test_non_timeout_policies_release_immediately():
    for p in (BatchAllWaiting(), CappedBatch(cap=4)):
        assert p.release_time(3.5, 1.0, 7) == 3.5


def test_take_values_pin():
    assert BatchAllWaiting().take(17) == 17
    assert CappedBatch(cap=8).take(17) == 8
    assert TimeoutBatch(cap=8).take(17) == 8


# --------------------------------------------------------------------------
# Admission-control accounting laws (loss_ref over random configurations)
# --------------------------------------------------------------------------

_MODEL = LinearServiceModel(alpha=0.05, tau0=1.0)


def _loss_point(lam, q_max, deadline, overflow_i, retry_rate, seed,
                n_batches=2500):
    return simulate_loss_numpy(
        lam, _MODEL, 8, q_max=q_max, deadline=deadline,
        overflow=("reject", "drop")[overflow_i], retry_rate=retry_rate,
        q_cap=128, r_cap=64, n_batches=n_batches, seed=seed)


@settings(max_examples=12, deadline=None)
@given(lam=st.floats(min_value=1.0, max_value=9.0),
       q_max=st.integers(min_value=1, max_value=40),
       deadline=st.floats(min_value=0.0, max_value=12.0),
       overflow_i=st.integers(min_value=0, max_value=1),
       retry_rate=st.floats(min_value=0.0, max_value=1.0),
       seed=st.integers(min_value=0, max_value=10_000))
def test_terminal_classes_partition_offered(lam, q_max, deadline,
                                            overflow_i, retry_rate,
                                            seed):
    r = _loss_point(lam, q_max, deadline, overflow_i, retry_rate, seed)
    assert r.offered == r.n_jobs + r.overflow_dropped + r.abandoned
    for f in (r.goodput_frac, r.reject_frac, r.abandon_frac,
              r.late_frac):
        assert -1e-12 <= f <= 1.0 + 1e-12
    assert (r.goodput_frac + r.late_frac + r.reject_frac
            + r.abandon_frac) == pytest.approx(1.0, abs=1e-9)
    assert r.retry_inflation >= 1.0 - 1e-12
    assert r.n_in_slo <= r.n_jobs <= r.offered


@settings(max_examples=12, deadline=None)
@given(lam=st.floats(min_value=1.0, max_value=9.0),
       q_max=st.integers(min_value=1, max_value=40),
       deadline=st.floats(min_value=0.0, max_value=12.0),
       overflow_i=st.integers(min_value=0, max_value=1),
       retry_rate=st.floats(min_value=0.0, max_value=1.0),
       seed=st.integers(min_value=0, max_value=10_000))
def test_goodput_below_throughput_below_offered_rate(lam, q_max,
                                                     deadline,
                                                     overflow_i,
                                                     retry_rate, seed):
    """As rates over the offered stream: λ·goodput_frac ≤
    λ·(completing fraction) ≤ λ — admission control can only shed or
    delay work, never manufacture it."""
    r = _loss_point(lam, q_max, deadline, overflow_i, retry_rate, seed)
    complete_frac = 1.0 - r.reject_frac - r.abandon_frac
    assert r.goodput_frac <= complete_frac + 1e-12
    assert complete_frac <= 1.0 + 1e-12


@settings(max_examples=8, deadline=None)
@given(lam=st.floats(min_value=3.0, max_value=8.0),
       q_max=st.integers(min_value=4, max_value=24),
       deadline=st.floats(min_value=2.0, max_value=8.0),
       overflow_i=st.integers(min_value=0, max_value=1),
       seed=st.integers(min_value=0, max_value=10_000))
def test_goodput_monotone_in_deadline_at_fixed_seed(lam, q_max,
                                                    deadline,
                                                    overflow_i, seed):
    """Tightening ONLY the deadline at a fixed seed cannot raise the
    goodput fraction (small MC slack: reneging perturbs the queue path,
    so the comparison is statistical, not path-wise)."""
    fracs = [
        _loss_point(lam, q_max, deadline * s, overflow_i, 0.0, seed,
                    n_batches=4000).goodput_frac
        for s in (1.5, 1.0, 1.0 / 1.5)]
    assert fracs[0] >= fracs[1] - 0.02
    assert fracs[1] >= fracs[2] - 0.02
