"""Simulation/Markov cross-validation of the paper's main claims."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import analytic as an
from repro.core.analytic import LinearServiceModel
from repro.core.energy import eta_from_batches, eta_given_EB, eta_lower
from repro.core.markov import solve
from repro.core.simulate import simulate
from repro.core.stochastic import a_pmf, st_leq

V100 = LinearServiceModel(alpha=0.1438, tau0=1.8874)


class TestSimVsMarkov:
    """The event simulator and the truncated-chain solver must agree —
    two independent implementations of the same exact model."""

    @pytest.mark.parametrize("rho", [0.2, 0.5, 0.8])
    def test_mean_latency_agreement(self, rho):
        lam = rho / V100.alpha
        s = simulate(lam, V100, n_jobs=200_000, seed=7)
        m = solve(lam, V100)
        assert s.mean_latency == pytest.approx(m.mean_latency, rel=0.03)
        assert s.mean_batch == pytest.approx(m.mean_batch, rel=0.05)
        assert s.utilization == pytest.approx(m.utilization, abs=0.01)

    @pytest.mark.parametrize("b_max", [4, 16, 64])
    def test_finite_bmax_agreement(self, b_max):
        lam = 0.6 * b_max / (V100.alpha * b_max + V100.tau0)
        s = simulate(lam, V100, n_jobs=150_000, b_max=b_max, seed=3)
        m = solve(lam, V100, b_max=b_max)
        assert s.mean_latency == pytest.approx(m.mean_latency, rel=0.04)
        assert s.mean_batch <= b_max and m.mean_batch <= b_max + 1e-9


class TestTheorem2:
    """E[W] ≤ φ = min(φ0, φ1), and the bound is tight (paper Fig. 4)."""

    @pytest.mark.parametrize("rho", [0.1, 0.3, 0.5, 0.7, 0.9])
    def test_bound_holds_and_tight(self, rho):
        lam = rho / V100.alpha
        m = solve(lam, V100)
        bound = float(an.phi(lam, V100.alpha, V100.tau0))
        assert m.mean_latency <= bound * (1 + 1e-9)
        if rho >= 0.3:
            # paper: φ1 nearly exact once utilization saturates
            assert m.mean_latency == pytest.approx(bound, rel=0.02)

    @pytest.mark.parametrize("gpu", [V100,
                                     LinearServiceModel(0.5833, 1.4284)])
    def test_bound_holds_p4_too(self, gpu):
        for rho in (0.25, 0.6, 0.85):
            lam = rho / gpu.alpha
            m = solve(lam, gpu)
            assert m.mean_latency <= float(
                an.phi(lam, gpu.alpha, gpu.tau0)) * (1 + 1e-9)

    def test_finite_bmax_approx(self):
        """Fig. 8: for moderate load the infinite-b_max formula still
        approximates the finite-b_max system."""
        b_max = 64
        lam = 0.5 / V100.alpha
        m = solve(lam, V100, b_max=b_max)
        assert m.mean_latency == pytest.approx(
            float(an.phi(lam, V100.alpha, V100.tau0)), rel=0.05)

    def test_utilization_saturates(self):
        """Fig. 5: utilization ≈ 1 at moderate ρ (unlike M/D/1)."""
        lam = 0.4 / V100.alpha
        m = solve(lam, V100)
        assert m.utilization > 0.99
        assert m.utilization <= min(1.0, lam * (V100.alpha + V100.tau0))


class TestTheorem1:
    """Monotonicity: batch sizes and energy efficiency increase with λ."""

    def test_st_order_of_A(self):
        """(23)/(24): A^[b],λ stochastically increasing in b and in λ."""
        kmax = 400
        for dist in ("det", "exp"):
            p_small = a_pmf(2.0, 4, V100, kmax, dist)
            p_big = a_pmf(2.0, 16, V100, kmax, dist)
            assert st_leq(p_small, p_big)
            p_lo = a_pmf(1.0, 8, V100, kmax, dist)
            p_hi = a_pmf(3.0, 8, V100, kmax, dist)
            assert st_leq(p_lo, p_hi)

    def test_batch_size_st_increasing_in_lambda(self):
        """Theorem 1 on the solved chain: survival of B grows with λ."""
        lams = [1.0, 2.0, 4.0, 6.0]
        survs = []
        K = 900
        for lam in lams:
            m = solve(lam, V100, truncation=K)
            b_of = np.minimum(np.maximum(np.arange(K + 1), 1), K + 1)
            pmf = np.zeros(K + 2)
            for l, pl_ in enumerate(m.pi):
                pmf[b_of[l]] += pl_
            survs.append(pmf[::-1].cumsum()[::-1])
        for lo, hi in zip(survs, survs[1:]):
            assert np.all(lo <= hi + 1e-9)

    def test_energy_efficiency_monotone(self):
        """Corollary 1 on simulation: η non-decreasing in λ."""
        beta, c0 = 0.05, 0.2
        etas = []
        for rho in (0.1, 0.3, 0.5, 0.7, 0.9):
            s = simulate(rho / V100.alpha, V100, n_jobs=120_000, seed=11)
            etas.append(s.eta(beta, c0))
        assert all(b >= a - 1e-3 for a, b in zip(etas, etas[1:])), etas

    def test_eta_lower_bound(self):
        beta, c0 = 0.05, 0.2
        for rho in (0.2, 0.5, 0.8):
            lam = rho / V100.alpha
            s = simulate(lam, V100, n_jobs=120_000, seed=5)
            lb = float(eta_lower(lam, V100.alpha, V100.tau0, beta, c0))
            assert s.eta(beta, c0) >= lb * (1 - 0.02)
            # exact form (19) with simulated E[B]
            assert s.eta(beta, c0) == pytest.approx(
                float(eta_given_EB(s.mean_batch, beta, c0)), rel=0.02)


class TestServiceDistributions:
    """Example 1 families: the latency ordering H det ≤ gamma ≤ exp
    (increasing variability ⇒ larger mean latency)."""

    def test_variability_ordering(self):
        lam = 0.5 / V100.alpha
        w = {}
        for dist in ("det", "gamma", "exp"):
            s = simulate(lam, V100, n_jobs=150_000, dist=dist, cv=0.5,
                         seed=13)
            w[dist] = s.mean_latency
        assert w["det"] < w["gamma"] < w["exp"]


@given(rho=st.floats(0.05, 0.9), alpha=st.floats(0.05, 2.0),
       tau0=st.floats(0.05, 5.0))
@settings(max_examples=25, deadline=None)
def test_property_sim_below_bound(rho, alpha, tau0):
    """Property: simulated E[W] ≤ φ within statistical tolerance."""
    m = LinearServiceModel(alpha, tau0)
    lam = rho / alpha
    if lam * tau0 / (1 - rho) > 200:   # keep runtime bounded
        return
    s = simulate(lam, m, n_jobs=60_000, seed=1)
    assert s.mean_latency <= float(an.phi(lam, alpha, tau0)) * 1.08


class TestLemmaIdentities:
    """The paper's exact identities evaluated on the independently solved
    chain — a strong cross-check of theory vs numerics."""

    @pytest.mark.parametrize("rho", [0.2, 0.5, 0.8])
    def test_lemma3_EB_identity(self, rho):
        """Eq (31): E[B] = (λτ0 + Pr(A=0)) / (1 − λα), with Pr(A=0) taken
        from the solved chain."""
        from repro.core.markov import poisson_pmf_row
        lam = rho / V100.alpha
        m = solve(lam, V100)
        K = m.truncation
        b_of = np.minimum(np.maximum(np.arange(K + 1), 1), K + 1)
        p_a0 = sum(pl * float(np.exp(-lam * V100.tau(int(b))))
                   for pl, b in zip(m.pi, b_of))
        eb_pred, eb2_pred = an.batch_moments_given_pA0(
            lam, V100.alpha, V100.tau0, p_a0)
        assert m.mean_batch == pytest.approx(eb_pred, rel=2e-3)
        assert m.batch_m2 == pytest.approx(eb2_pred, rel=5e-3)

    @pytest.mark.parametrize("rho", [0.2, 0.5, 0.8])
    def test_lemma2_EW_identity(self, rho):
        """Eq (36): E[W] = α + τ0 + (1+2λα)(E[B²]−E[B])/(2λE[B]) evaluated
        with the chain's own batch moments must equal the chain's E[W]."""
        lam = rho / V100.alpha
        m = solve(lam, V100)
        ew = float(an.mean_latency_given_batch_moments(
            lam, V100.alpha, V100.tau0, m.mean_batch, m.batch_m2))
        assert m.mean_latency == pytest.approx(ew, rel=2e-3)

    @pytest.mark.parametrize("rho", [0.2, 0.5, 0.8])
    def test_eq38_utilization_identity(self, rho):
        """Eq (38): 1 − π0 = λα + λτ0/E[B]."""
        lam = rho / V100.alpha
        m = solve(lam, V100)
        util = float(an.utilization_given_EB(lam, V100.alpha, V100.tau0,
                                             m.mean_batch))
        assert m.utilization == pytest.approx(util, rel=2e-3)
