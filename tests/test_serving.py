"""End-to-end serving-engine tests: real model, dynamic batching, Poisson
load — the system-level behaviour the paper characterizes."""
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import BatchAllWaiting, CappedBatch, TimeoutBatch, phi
from repro.core.calibrate import fit_service_model
from repro.serving import InferenceEngine


@pytest.fixture(scope="module")
def engine():
    cfg = reduced(get_config("qwen1.5-0.5b"))
    eng = InferenceEngine(cfg, workload="forward", seq_len=32, max_batch=16)
    eng.warmup()
    return eng


def test_calibration_linear(engine):
    b, t = engine.calibrate(samples=3)
    model, r2 = fit_service_model(b, t)
    assert model.alpha > 0 and model.tau0 > 0
    assert r2 > 0.8          # CPU noise allowed; trend must be linear
    # throughput increases with batch size (Assumption 1(i))
    mu = b / t
    assert mu[-1] > mu[0]


def test_serve_poisson_basic(engine):
    model, _ = engine.fit_service_model(samples=3)
    lam = 0.3 / model.alpha
    res = engine.serve_poisson(lam, n_jobs=120, seed=0)
    assert res.n_jobs == 120
    assert res.mean_latency > 0
    assert 1.0 <= res.mean_batch <= engine.max_batch
    assert 0 < res.utilization <= 1.0
    # sojourn ≥ the single-job service floor for every request
    assert res.latencies.min() >= model.tau0 * 0.2


def test_batching_kicks_in_under_load(engine):
    model, _ = engine.fit_service_model(samples=3)
    lo = engine.serve_poisson(0.05 / model.alpha, n_jobs=60, seed=1)
    hi = engine.serve_poisson(0.6 / model.alpha, n_jobs=200, seed=1)
    assert hi.mean_batch > lo.mean_batch   # Theorem 1 in the real system


def test_capped_policy_respects_bmax(engine):
    model, _ = engine.fit_service_model(samples=3)
    res = engine.serve_poisson(0.5 / model.alpha, n_jobs=150,
                               policy=CappedBatch(cap=4), seed=2)
    assert res.batch_sizes.max() <= 4


def test_timeout_policy_increases_batch(engine):
    """Timeout batching accumulates larger batches at light load (and pays
    latency for it — the beyond-paper comparison)."""
    model, _ = engine.fit_service_model(samples=3)
    lam = 0.15 / model.alpha
    nowait = engine.serve_poisson(lam, n_jobs=100,
                                  policy=BatchAllWaiting(), seed=3)
    wait = engine.serve_poisson(
        lam, n_jobs=100,
        policy=TimeoutBatch(max_wait=20 * model.tau0, target=8, cap=16),
        seed=3)
    assert wait.mean_batch >= nowait.mean_batch
    assert wait.mean_latency >= nowait.mean_latency * 0.9


def test_measured_latency_tracks_phi(engine):
    """Fig.-11 analogue: measured E[W] is the same order as φ(λ) and the
    bound degrades gracefully (buckets/noise put the real curve near or
    above φ, never far below)."""
    model, _ = engine.fit_service_model(samples=3)
    lam = 0.4 / model.alpha
    res = engine.serve_poisson(lam, n_jobs=250, seed=4)
    bound = float(phi(lam, model.alpha, model.tau0))
    assert res.mean_latency > 0.3 * bound
    assert res.mean_latency < 10.0 * bound


def test_generate_workload():
    cfg = reduced(get_config("qwen1.5-0.5b"))
    eng = InferenceEngine(cfg, workload="generate", seq_len=16,
                          gen_tokens=3, max_batch=4)
    t = eng.run_batch(2)
    assert t > 0
    res = eng.serve_poisson(5.0, n_jobs=12, seed=0)
    assert res.n_jobs == 12


def test_bucketing_is_stairlike(engine):
    """Bucketed execution: batch 3 runs at the bucket-4 cost (the stair
    structure the paper observes on ResNet50)."""
    assert engine.bucket_of(3) == 4
    assert engine.bucket_of(4) == 4
    assert engine.bucket_of(5) == 8
    assert engine.bucket_of(16) == 16
