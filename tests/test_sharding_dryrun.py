"""Sharding-spec unit tests + one real subprocess dry-run integration test."""
import json
import os
import subprocess
import sys
from types import SimpleNamespace

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.launch import sharding as shd
from repro.models import registry as reg
from repro.models import transformer as tfm

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def mesh16() -> SimpleNamespace:
    """Shape-only stand-in for the 16×16 production mesh (no devices)."""
    return SimpleNamespace(shape={"data": 16, "model": 16},
                           axis_names=("data", "model"))


class TestGuards:
    def test_divisible_kept(self):
        m = mesh16()
        assert shd._guard((None, "model"), (10, 32), m) == P(None, "model")

    def test_non_divisible_replicated(self):
        m = mesh16()
        assert shd._guard((None, "model"), (10, 20), m) == P(None, None)

    def test_tuple_axes(self):
        m = SimpleNamespace(shape={"pod": 2, "data": 16, "model": 16},
                            axis_names=("pod", "data", "model"))
        assert shd._guard((("pod", "data"), None), (64, 7), m) == \
            P(("pod", "data"), None)
        assert shd._guard((("pod", "data"), None), (48, 7), m) == P(None,
                                                                    None)


class TestParamSpecs:
    @pytest.mark.parametrize("arch", ["qwen1.5-4b", "olmoe-1b-7b",
                                      "mamba2-2.7b",
                                      "deepseek-v2-lite-16b"])
    def test_specs_cover_all_params(self, arch):
        cfg = get_config(arch)
        shape = jax.eval_shape(
            lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))
        specs = shd.param_specs(cfg, shape, mesh16())
        # same structure, every leaf a PartitionSpec with matching rank
        flat_s, _ = jax.tree_util.tree_flatten(
            specs, is_leaf=lambda x: isinstance(x, P))
        flat_p = jax.tree.leaves(shape)
        assert len(flat_s) == len(flat_p)
        for sp, leaf in zip(flat_s, flat_p):
            assert isinstance(sp, P)
            assert len(sp) <= leaf.ndim

    def test_qwen4b_head_fallback(self):
        """20 heads don't divide 16 → head_dim sharding must kick in."""
        cfg = get_config("qwen1.5-4b")
        shape = jax.eval_shape(
            lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))
        specs = shd.param_specs(cfg, shape, mesh16())
        wq = specs["stack"][0]["attn"]["wq"]
        assert wq == P(None, None, None, "model")   # stacked + hd sharding

    def test_moe_expert_parallel(self):
        cfg = get_config("olmoe-1b-7b")
        shape = jax.eval_shape(
            lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))
        specs = shd.param_specs(cfg, shape, mesh16())
        wg = specs["stack"][0]["ffn"]["w_gate"]
        assert wg == P(None, "model", None, None)   # (R, E, d, f): E→model


class TestInputSpecs:
    def test_decode_cache_fully_sharded(self):
        """§Perf D1 layout: batch over data, cache sequence over model —
        the KV cache is fully sharded regardless of kv-head divisibility."""
        cfg = get_config("qwen1.5-0.5b")
        inp = reg.input_specs(cfg, SHAPES["decode_32k"])
        specs = shd.input_spec_tree(cfg, SHAPES["decode_32k"], mesh16(),
                                    inp)
        k = specs["cache"]["stack"][0]["k"]
        # (stack, B, S, KV, hd): batch over data, sequence over model
        assert k == P(None, "data", "model", None, None)
        assert specs["tokens"] == P("data", None)

    def test_long500k_sequence_sharded(self):
        cfg = get_config("qwen1.5-0.5b")
        inp = reg.input_specs(cfg, SHAPES["long_500k"])
        specs = shd.input_spec_tree(cfg, SHAPES["long_500k"], mesh16(), inp)
        k = specs["cache"]["stack"][0]["k"]
        # (stack, B=1, S, KV, hd): batch replicated, sequence over BOTH
        # axes (524288 / 256 = 2048 slots per device)
        assert k[1] is None
        assert k[2] == ("data", "model")


@pytest.mark.slow
def test_dryrun_subprocess_single_combo():
    """Full integration: 512 fake devices, production mesh, lower+compile."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "qwen1.5-0.5b", "--shape", "decode_32k", "--mesh", "both"],
        capture_output=True, text=True, env=env, timeout=900, check=True)
    lines = [json.loads(l) for l in out.stdout.strip().splitlines()]
    assert len(lines) == 2
    assert all(r["ok"] for r in lines)
    meshes = {r["mesh"] for r in lines}
    assert meshes == {"16x16", "2x16x16"}
