"""Tests for the unified superstep engine (``repro.core.engine``).

Three contracts:

- **Shard invariance**: all three sweep kernels produce bitwise-
  identical per-point results under 1, 2, and 4 forced host devices.
  Runs in a subprocess with ``XLA_FLAGS=--xla_force_host_platform_``
  ``device_count=4`` (the flag only takes effect before JAX backend
  initialization, which the parent test process has long passed), and
  parametrizes the mesh size via the kernels' ``shard`` argument.
- **Shared grid padding**: point counts not divisible by the shard
  count pad by repeating the last point and slice back — one
  implementation (``engine.pad_tail``/``engine.dispatch``) for every
  kernel, exercised directly and through the kernels (5 points over 4
  shards in the subprocess).
- **Bounded kernel caches**: the LRU actually evicts — size stays at
  ``maxsize``, eviction releases the compiled programs
  (``clear_cache``), and a re-requested evicted shape rebuilds.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import engine

# ---------------------------------------------------------------------------
# adaptive capacity sizing
# ---------------------------------------------------------------------------


class TestAdaptiveCaps:
    def test_queue_capacity_monotone_in_load(self):
        alpha, tau0 = 0.1438, 1.8874
        caps = [engine.queue_capacity([rho / alpha], [alpha], [tau0],
                                      [0], [0.0])
                for rho in (0.1, 0.5, 0.9)]
        assert caps == sorted(caps)
        assert caps[0] >= 64                       # floor
        assert caps[-1] <= 8192                    # ceiling
        assert all(c & (c - 1) == 0 for c in caps)  # pow2 bucketed

    def test_queue_capacity_covers_bmax(self):
        c = engine.queue_capacity([0.01], [0.1], [1.0], [700], [0.0])
        assert c >= 1400

    def test_queue_capacity_light_grids_shrink(self):
        """The point of adaptive sizing: a light grid stops paying the
        old global worst case (1024)."""
        alpha, tau0 = 0.1438, 1.8874
        light = engine.queue_capacity(
            [0.3 / alpha], [alpha], [tau0], [0], [0.0])
        assert light < 1024

    def test_window_capacity(self):
        a = engine.window_capacity([0.145], [300.0])
        assert a % 16 == 0 and a >= 0.145 * 300
        assert engine.window_capacity([1e-9], [1.0], slack=0.0) == 16


# ---------------------------------------------------------------------------
# shared padding + dispatch
# ---------------------------------------------------------------------------


class TestPadding:
    def test_pad_tail_repeats_last_point(self):
        a = engine.pad_tail(np.arange(5.0), 3)
        assert np.array_equal(np.asarray(a),
                              [0.0, 1.0, 2.0, 3.0, 4.0, 4.0, 4.0, 4.0])
        b = np.arange(6.0).reshape(3, 2)
        padded = np.asarray(engine.pad_tail(b, 2))
        assert padded.shape == (5, 2)
        assert np.array_equal(padded[3], b[-1])
        assert engine.pad_tail(a, 0) is a          # no-op passthrough

    def test_dispatch_pads_and_slices_back(self):
        """``dispatch`` pads every input's point axis to a shard-
        divisible count and slices the outputs back — checked through a
        trivial jitted kernel with a deliberately indivisible count."""
        import jax
        import jax.numpy as jnp

        calls = {}

        @jax.jit
        def kernel(params, keys):
            return {"x": params["a"] * 2.0,
                    "k": keys[:, 0]}

        def probe(params, keys):
            calls["n"] = int(params["a"].shape[0])
            return kernel(params, keys)

        params = {"a": jnp.arange(5.0)}
        keys = engine.point_keys(0, 0, 5)
        out = engine.dispatch(probe, params, keys, 5, 4)
        assert calls["n"] == 8                     # padded to 4-divisible
        assert out["x"].shape == (5,)              # sliced back
        assert np.array_equal(out["x"], 2.0 * np.arange(5.0))

    def test_resolve_shards(self):
        import jax
        avail = len(jax.devices())
        assert engine.resolve_shards(False, 100) == 1
        assert engine.resolve_shards(None, 100) == avail
        assert engine.resolve_shards(1, 100) == 1
        # ints clamp to availability and point count (shard-invariant
        # results make clamping harmless)
        assert engine.resolve_shards(64, 100) == avail
        assert engine.resolve_shards(None, 1) == 1
        with pytest.raises(ValueError):
            engine.resolve_shards(0, 4)


# ---------------------------------------------------------------------------
# bounded kernel caches
# ---------------------------------------------------------------------------


class _FakeKernel:
    def __init__(self):
        self.cleared = False

    def clear_cache(self):
        self.cleared = True


class TestKernelCache:
    def test_lru_evicts_and_releases(self):
        """Regression: the cache must actually evict — bounded size,
        FIFO-by-recency order, compiled programs released via
        ``clear_cache`` — and rebuild evicted shapes on demand."""
        built = []

        @engine.kernel_cache(maxsize=2)
        def build(shape):
            k = _FakeKernel()
            built.append((shape, k))
            return k

        k0, k1 = build(0), build(1)
        assert build(0) is k0                      # hit, no rebuild
        assert build.builds == 2
        build(0)                                   # 0 most recent
        k2 = build(2)                              # evicts 1, not 0
        assert build.cache_len() == 2
        assert build.evictions == 1
        assert k1.cleared and not k0.cleared and not k2.cleared
        assert build(0) is k0                      # survivor still cached
        assert build(1) is not k1                  # evicted -> rebuilt
        assert build.builds == 4
        build.cache_clear()
        assert build.cache_len() == 0 and k0.cleared

    def test_kernel_builders_are_bounded(self):
        """Every per-shape kernel builder (the three sweep kernels and
        the chain solver's grid kernel) sits behind the evicting LRU."""
        from repro.core import chain_solver, gen_sweep, sweep
        for builder, bound in ((sweep._build_kernel, 32),
                               (sweep._build_fleet_kernel, 16),
                               (gen_sweep._build_gen_kernel, 16),
                               (chain_solver._build_grid_kernel, 8)):
            assert isinstance(builder, engine._KernelCache)
            assert builder.maxsize == bound

    def test_jitted_kernels_release_compiled_programs(self):
        """End to end on a real jitted builder: eviction drops the
        compiled-program count back (``clear_cache`` works on jit
        wrappers)."""
        import jax
        import jax.numpy as jnp

        @engine.kernel_cache(maxsize=1)
        def build(n):
            return jax.jit(lambda x: x * n)

        f0 = build(2)
        f0(jnp.ones(3))
        assert f0._cache_size() == 1
        build(3)                                   # evicts f0
        assert f0._cache_size() == 0               # programs released


# ---------------------------------------------------------------------------
# shard invariance of the three kernels (subprocess: the forced host
# device count must be set before JAX backend initialization)
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = textwrap.dedent("""
    import numpy as np
    import jax

    assert len(jax.devices()) == 4, jax.devices()

    from repro.core.sweep import SweepGrid, FleetGrid, sweep, fleet_sweep
    from repro.core.gen_sweep import GenGrid, gen_sweep

    def check(name, runs):
        ref = runs[0]
        for r in runs[1:]:
            for field in ("mean_latency", "mean_batch", "utilization",
                          "n_jobs", "hist"):
                a, b = getattr(ref, field), getattr(r, field)
                assert np.array_equal(a, b), (name, field)
        assert int(ref.buffer_dropped.sum()) == 0, name
        print(name, "ok")

    # 5 points: indivisible by 2 and 4, so the shared repeated-last-
    # point padding is on the line for every sharded run
    g = SweepGrid.from_rhos([0.2, 0.4, 0.6, 0.8, 0.9], 0.1438, 1.8874)
    check("sweep", [sweep(g, n_batches=256, seed=7, shard=s)
                    for s in (1, 2, 4, None)])

    fg = FleetGrid.from_rhos([0.3, 0.7], 0.1438, 1.8874, ks=(1, 3),
                             routings=("random", "jsq")).take(slice(0, 7))
    assert len(fg) % 4 != 0 and len(fg) % 2 != 0
    check("fleet", [fleet_sweep(fg, n_steps=256, seed=3, shard=s)
                    for s in (1, 2, 4)])

    gg = GenGrid.from_points(
        [0.02] * 5, 0.14, 1.9, 0.035, 1.9, prompt_len=64,
        gen_tokens=16, max_active=8,
        discipline=["static", "continuous"] * 2 + ["static"])
    check("gen", [gen_sweep(gg, n_steps=2048, seed=11, shard=s)
                  for s in (1, 2, 4)])
""")


@pytest.mark.slow
def test_kernels_shard_invariant_under_forced_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert proc.stdout.split() == ["sweep", "ok", "fleet", "ok",
                                   "gen", "ok"], proc.stdout
