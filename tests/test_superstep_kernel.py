"""Fused superstep kernel (repro.kernels.superstep): backend
resolution, bitwise lax/pallas parity on all three sweep kernels (the
pallas path runs in interpret mode on CPU), the streaming-sketch mode,
the split-dispatch pinned-caps contract, and the kernel-cache keying
the backend flags ride on.

Parity is *bitwise* by design: histogram counts are integer
accumulations in both backends, and the fused FIFO compaction is the
same gather the lax pad+slice sequence lowers to.
"""
import numpy as np
import pytest

from repro.core import engine
from repro.core.analytic import LinearServiceModel
from repro.core.continuous_sim import GenServiceModel
from repro.core.gen_sweep import GenGrid, gen_caps, gen_sweep
from repro.core.grid import FleetGrid, SweepGrid
from repro.core.hist import SKETCH_BINS
from repro.core.sweep import (fleet_caps, fleet_sweep, sweep,
                              sweep_caps)
from repro.kernels import superstep as ss

V100 = LinearServiceModel(alpha=0.1438, tau0=1.8874)
GMODEL = GenServiceModel(alpha_decode=0.14, tau0_decode=1.9,
                         alpha_prefill=0.002, tau0_prefill=0.9)


def _sweep_grid():
    return SweepGrid.from_product([1.0, 2.5], [V100.alpha],
                                  [V100.tau0], b_maxes=(8,))


class TestResolveBackend:
    def test_explicit_wins(self, monkeypatch):
        monkeypatch.setenv(ss.ENV_VAR, "pallas")
        assert ss.resolve_backend("lax", n_bins=64) == "lax"
        assert ss.resolve_backend("pallas", n_bins=4096) == "pallas"

    def test_env_overrides_auto(self, monkeypatch):
        monkeypatch.setenv(ss.ENV_VAR, "lax")
        assert ss.resolve_backend(None, n_bins=64) == "lax"
        monkeypatch.setenv(ss.ENV_VAR, "pallas")
        assert ss.resolve_backend("auto", n_bins=512) == "pallas"

    def test_auto_is_bin_count_aware_on_cpu(self, monkeypatch):
        import jax
        monkeypatch.delenv(ss.ENV_VAR, raising=False)
        if jax.default_backend() in ("tpu", "gpu"):
            assert ss.resolve_backend(None, n_bins=512) == "pallas"
        else:
            assert ss.resolve_backend(
                None, n_bins=ss.PALLAS_CPU_MAX_BINS) == "pallas"
            assert ss.resolve_backend(None, n_bins=512) == "lax"

    def test_unknown_backend_raises(self, monkeypatch):
        with pytest.raises(ValueError, match="unknown superstep"):
            ss.resolve_backend("nope", n_bins=64)
        monkeypatch.setenv(ss.ENV_VAR, "bogus")
        with pytest.raises(ValueError, match="unknown superstep"):
            ss.resolve_backend(None, n_bins=64)


class TestFusedOps:
    """The two fused ops against their lax references, standalone."""

    def test_hist_update_bitwise(self):
        import jax
        import jax.numpy as jnp

        rng = np.random.default_rng(0)
        lats = jnp.asarray(rng.lognormal(1.0, 1.5, (32, 16)),
                           dtype=jnp.float32)
        inc = jnp.asarray(rng.random((32, 16)) < 0.7)
        h0 = (jnp.zeros((512,), jnp.int32),)

        def run(backend):
            return jax.jit(lambda h, l, i: ss.hist_update(
                h, l, i, n_bins=512, backend=backend))(h0, lats, inc)
        out_l, out_p = run("lax"), run("pallas")
        assert np.array_equal(out_l[0], out_p[0])
        assert int(np.sum(out_l[0])) == int(np.sum(np.asarray(inc)))

    def test_hist_update_sketch_sums(self):
        import jax
        import jax.numpy as jnp

        rng = np.random.default_rng(1)
        lats = jnp.asarray(rng.lognormal(0.5, 1.0, (16, 8)),
                           dtype=jnp.float32)
        inc = jnp.asarray(rng.random((16, 8)) < 0.5)
        h0 = (jnp.zeros((SKETCH_BINS,), jnp.int32),
              jnp.zeros((SKETCH_BINS,), jnp.float32))

        def run(backend):
            return jax.jit(lambda h, l, i: ss.hist_update(
                h, l, i, n_bins=SKETCH_BINS, backend=backend,
                sketch=True))(h0, lats, inc)
        out_l, out_p = run("lax"), run("pallas")
        assert np.array_equal(out_l[0], out_p[0])       # counts: bitwise
        np.testing.assert_allclose(out_l[1], out_p[1], rtol=1e-6)
        # per-bin sums integrate exactly the counted latencies
        want = float(np.sum(np.where(np.asarray(inc),
                                     np.asarray(lats), 0.0)))
        assert float(np.sum(out_l[1])) == pytest.approx(want, rel=1e-6)

    def test_fifo_compact_matches_pop_shift(self):
        import jax
        import jax.numpy as jnp

        buf = jnp.asarray(np.arange(10, 26, dtype=np.float32))
        for k in (0, 3, 16):
            now = jnp.float32(2.5)
            kk = jnp.int32(k)
            out_l = jax.jit(lambda b, k_, n: ss.fifo_compact(
                b, k_, n, backend="lax"))(buf, kk, now)
            out_p = jax.jit(lambda b, k_, n: ss.fifo_compact(
                b, k_, n, backend="pallas"))(buf, kk, now)
            assert np.array_equal(out_l, out_p), k
        with pytest.raises(ValueError, match="unresolved"):
            ss.fifo_compact(buf, jnp.int32(1), jnp.float32(0.0),
                            backend="auto")


class TestBackendParity:
    """Whole-kernel dispatches, lax vs pallas, bitwise."""

    def test_sweep_parity(self):
        g = _sweep_grid()
        kw = dict(n_batches=256, q_cap=64, seed=3)
        rl = sweep(g, superstep_backend="lax", **kw)
        rp = sweep(g, superstep_backend="pallas", **kw)
        assert np.array_equal(rl.hist, rp.hist)
        for f in ("mean_latency", "n_jobs", "latency_p99"):
            assert np.array_equal(getattr(rl, f), getattr(rp, f)), f
        assert rl.hist_sums is None

    def test_sweep_sketch_parity_and_totals(self):
        g = _sweep_grid()
        kw = dict(n_batches=256, q_cap=64, seed=3)
        full = sweep(g, superstep_backend="lax", **kw)
        rl = sweep(g, sketch=True, superstep_backend="lax", **kw)
        rp = sweep(g, sketch=True, superstep_backend="pallas", **kw)
        assert rl.hist.shape == (len(g), SKETCH_BINS)
        assert np.array_equal(rl.hist, rp.hist)
        assert rl.hist_sums is not None and rl.hist_sums.shape == \
            rl.hist.shape
        # the sketch re-bins the same measured jobs, never drops any
        assert np.array_equal(rl.hist.sum(axis=1),
                              full.hist.sum(axis=1))
        # sketch edges flow into the percentile reconstruction
        assert np.array_equal(rl.hist_bin_edges, rp.hist_bin_edges)
        assert len(rl.hist_bin_edges) == SKETCH_BINS + 1

    def test_gen_parity(self):
        g = GenGrid.from_product([0.05, 0.1], GMODEL,
                                 prompt_lens=(128,), gen_tokens=(16,),
                                 max_actives=(8,),
                                 disciplines=("continuous",))
        kw = dict(n_steps=256, q_cap=64, a_cap=16, seed=5)
        rl = gen_sweep(g, superstep_backend="lax", **kw)
        rp = gen_sweep(g, superstep_backend="pallas", **kw)
        assert np.array_equal(rl.hist, rp.hist)
        for f in ("mean_latency", "n_jobs", "mean_batch"):
            assert np.array_equal(getattr(rl, f), getattr(rp, f)), f

    def test_fleet_parity_with_thinning(self):
        g = FleetGrid.from_points([2.0, 4.0], V100.alpha, V100.tau0,
                                  k=[2, 2])
        kw = dict(n_steps=256, q_cap=64, a_cap=16, hist_every=2,
                  seed=7)
        rl = fleet_sweep(g, superstep_backend="lax", **kw)
        rp = fleet_sweep(g, superstep_backend="pallas", **kw)
        assert np.array_equal(rl.hist, rp.hist)
        for f in ("mean_latency", "n_jobs"):
            assert np.array_equal(getattr(rl, f), getattr(rp, f)), f


class TestSplitCapsContract:
    """key_offset != 0 (a chunk of a split campaign) must pin every
    grid-derived capacity — PR 6 documented the footgun, this enforces
    it (and the *_caps helpers make pinning one line)."""

    def test_sweep_split_requires_pinned_caps(self):
        g = _sweep_grid()
        with pytest.raises(ValueError, match="sweep_caps"):
            sweep(g.take(slice(1, None)), n_batches=64, seed=0,
                  key_offset=1)

    def test_gen_split_requires_pinned_caps(self):
        g = GenGrid.from_product([0.05, 0.1], GMODEL,
                                 prompt_lens=(128,), gen_tokens=(16,),
                                 max_actives=(8,),
                                 disciplines=("continuous",))
        with pytest.raises(ValueError, match="gen_caps"):
            gen_sweep(g.take(slice(1, None)), n_steps=64, seed=0,
                      key_offset=1)

    def test_fleet_split_requires_pinned_caps(self):
        g = FleetGrid.from_points([2.0, 4.0], V100.alpha, V100.tau0,
                                  k=[2, 2])
        with pytest.raises(ValueError, match="fleet_caps"):
            fleet_sweep(g.take(slice(1, None)), n_steps=64, seed=0,
                        key_offset=1)

    def test_caps_pinned_split_is_bitwise_whole(self):
        g = SweepGrid.from_product([1.0, 2.0, 3.0], [V100.alpha],
                                   [V100.tau0], b_maxes=(8,))
        caps = sweep_caps(g)
        assert set(caps) == {"q_cap", "a_cap"}
        kw = dict(n_batches=256, seed=11, **caps)
        full = sweep(g, **kw)
        a = sweep(g.take(slice(0, 2)), **kw)
        b = sweep(g.take(slice(2, None)), key_offset=2, **kw)
        for f in ("mean_latency", "n_jobs"):
            assert np.array_equal(
                getattr(full, f),
                np.concatenate([getattr(a, f), getattr(b, f)])), f
        assert np.array_equal(full.hist,
                              np.concatenate([a.hist, b.hist]))

    def test_caps_helpers_cover_loss_grids(self):
        g = SweepGrid.from_product([1.0], [V100.alpha], [V100.tau0],
                                   b_maxes=(8,), q_maxes=(16,),
                                   retry_rates=(0.1,))
        caps = sweep_caps(g)
        assert "r_cap" in caps
        fg = FleetGrid.from_points([2.0], V100.alpha, V100.tau0, k=[2])
        assert set(fleet_caps(fg)) == {"q_cap"}
        gg = GenGrid.from_product([0.05], GMODEL, prompt_lens=(64,),
                                  gen_tokens=(8,), max_actives=(8,),
                                  disciplines=("continuous",))
        assert set(gen_caps(gg)) == {"q_cap", "a_cap"}


class TestKernelCacheKeys:
    """S4: the backend/sketch flags are kernel-builder arguments, so
    the LRU can never serve a kernel compiled for the other
    configuration."""

    def test_backend_and_sketch_get_distinct_entries(self):
        from repro.core import sweep as sweep_mod

        g = _sweep_grid()
        sweep_mod._build_kernel.cache_clear()
        kw = dict(n_batches=64, q_cap=32, seed=0)
        sweep(g, superstep_backend="lax", **kw)
        assert sweep_mod._build_kernel.cache_len() == 1
        sweep(g, superstep_backend="pallas", **kw)
        assert sweep_mod._build_kernel.cache_len() == 2
        sweep(g, superstep_backend="pallas", sketch=True, **kw)
        assert sweep_mod._build_kernel.cache_len() == 3
        # same config again: served from cache, no rebuild
        builds = sweep_mod._build_kernel.builds
        sweep(g, superstep_backend="lax", **kw)
        assert sweep_mod._build_kernel.builds == builds
        # both backends present in the key tuples
        flat = [str(k) for k in sweep_mod._build_kernel.cache_keys()]
        assert any("pallas" in k for k in flat)
        assert any("'lax'" in k for k in flat)

    def test_lru_no_key_collision_on_eviction(self):
        """Direct _KernelCache exercise: near-identical keys differing
        only in the backend slot stay distinct through eviction."""
        @engine.kernel_cache(maxsize=2)
        def build(shape, backend):
            return (shape, backend, object())

        a = build(64, "lax")
        b = build(64, "pallas")
        assert a is not b
        assert build(64, "lax") is a              # hit refreshes LRU
        build(128, "lax")                         # evicts (64, pallas)
        assert build.evictions == 1
        assert build.cache_len() == 2
        b2 = build(64, "pallas")                  # rebuilt, not stale
        assert b2 is not b and build.builds == 4
