"""Tests for the vectorized JAX sweep engine (repro.core.sweep) and the
unified evaluate() entry point.

Tolerances are statistical: the sweep and the scalar simulator use
independent RNG streams, so agreement is within Monte Carlo error of the
run lengths used here, not bit-exact.
"""
import math

import numpy as np
import pytest

from repro.core import analytic as an
from repro.core.analytic import LinearServiceModel
from repro.core.evaluate import evaluate
from repro.core.markov import solve
from repro.core.simulate import simulate
from repro.core.sweep import DIST_CODE, SweepGrid, sweep

V100 = LinearServiceModel(alpha=0.1438, tau0=1.8874)
RHOS = [0.2, 0.5, 0.8]


@pytest.fixture(scope="module")
def base_result():
    """One shared det/∞-b_max sweep across loads (jit cache warm)."""
    grid = SweepGrid.from_rhos(RHOS, V100.alpha, V100.tau0)
    return grid, sweep(grid, n_batches=4000, q_cap=1024, seed=7)


class TestAgainstScalarSim:
    """vmap'd sweep ≈ the scalar NumPy simulator on a small grid."""

    def test_mean_latency_and_batches(self, base_result):
        grid, r = base_result
        assert int(r.buffer_dropped.sum()) == 0
        for i, rho in enumerate(RHOS):
            lam = rho / V100.alpha
            s = simulate(lam, V100, n_jobs=120_000, seed=3)
            assert r.mean_latency[i] == pytest.approx(s.mean_latency,
                                                      rel=0.05)
            assert r.mean_batch[i] == pytest.approx(s.mean_batch, rel=0.05)
            assert r.utilization[i] == pytest.approx(s.utilization,
                                                     abs=0.02)

    def test_finite_bmax(self):
        for b_max in (4, 16):
            lam = 0.6 * b_max / (V100.alpha * b_max + V100.tau0)
            g = SweepGrid.from_points([lam], [V100.alpha], [V100.tau0],
                                      b_max=[b_max])
            r = sweep(g, n_batches=6000, seed=5)
            s = simulate(lam, V100, n_jobs=120_000, b_max=b_max, seed=3)
            assert r.mean_latency[0] == pytest.approx(s.mean_latency,
                                                      rel=0.05)
            assert r.mean_batch[0] <= b_max + 1e-9

    def test_service_variability_ordering(self):
        """Example 1 families: E[W] det < gamma(cv=.5) < exp."""
        lam = 0.5 / V100.alpha
        g = SweepGrid.from_product([lam], [V100.alpha], [V100.tau0],
                                   dists=("det", "gamma", "exp"),
                                   cvs=(0.5,))
        r = sweep(g, n_batches=8000, q_cap=1024, seed=11)
        det, gam, exp_ = r.mean_latency
        assert det < gam < exp_


class TestPaperBoundsOnGrid:
    """Theorem 2 and Remark 5 hold across a (λ, α, τ0) grid."""

    def test_theorem2_det_infinite_bmax(self):
        grid = SweepGrid.from_product(
            [1.0, 2.0, 3.0], [0.1438, 0.25], [0.75, 1.8874])
        r = sweep(grid, n_batches=4000, q_cap=1024, seed=13)
        assert int(r.buffer_dropped.sum()) == 0
        bounds = np.array([an.phi(l, a, t) for l, a, t in
                           zip(grid.lam, grid.alpha, grid.tau0)])
        # the bound is tight at moderate/high load, so allow MC noise up
        assert np.all(r.mean_latency <= bounds * 1.05)

    def test_remark5_mean_batch_lower_bound(self):
        grid = SweepGrid.from_product(
            [1.0, 2.0, 3.0], [0.1438, 0.25], [0.75, 1.8874])
        r = sweep(grid, n_batches=4000, q_cap=1024, seed=17)
        lbs = np.array([an.mean_batch_lower(l, a, t) for l, a, t in
                        zip(grid.lam, grid.alpha, grid.tau0)])
        assert np.all(r.mean_batch >= lbs * 0.93)
        assert np.all(r.mean_batch >= 1.0)

    def test_matches_markov_exact(self, base_result):
        _, r = base_result
        for i, rho in enumerate(RHOS):
            m = solve(rho / V100.alpha, V100)
            assert r.mean_latency[i] == pytest.approx(m.mean_latency,
                                                      rel=0.04)
            assert r.batch_m2[i] == pytest.approx(m.batch_m2, rel=0.15)


class TestPolicies:
    def test_timeout_delay_hurts(self):
        """Under the paper's model, delaying for batch accumulation
        strictly increases mean latency vs batch-all-waiting."""
        lam = 0.3 / V100.alpha
        g = SweepGrid.from_points(
            [lam, lam], [V100.alpha], [V100.tau0], b_max=[0, 64],
            wait_max=[0.0, 5.0], wait_target=[0, 32])
        r = sweep(g, n_batches=5000, seed=19)
        assert r.mean_latency[1] > r.mean_latency[0] * 1.2

    def test_cap_harmless_until_it_binds(self):
        lam = 0.4 / V100.alpha
        g = SweepGrid.from_points([lam, lam], [V100.alpha], [V100.tau0],
                                  b_max=[0, 64])
        r = sweep(g, n_batches=5000, seed=23)
        assert r.mean_latency[1] == pytest.approx(r.mean_latency[0],
                                                  rel=0.05)


class TestResultSchema:
    def test_percentiles_ordered_and_results_consistent(self, base_result):
        _, r = base_result
        assert np.all(r.latency_p50 <= r.latency_p95)
        assert np.all(r.latency_p95 <= r.latency_p99)
        assert np.all(r.latency_p50 <= r.mean_latency * 1.5)
        for res in r.to_results():
            res.check()
            assert res.backend == "sweep"
            assert res.n_jobs > 0

    def test_percentiles_match_scalar(self, base_result):
        """Histogram percentiles within a few % of exact sample ones."""
        _, r = base_result
        i = 1                                       # rho = 0.5
        s = simulate(RHOS[i] / V100.alpha, V100, n_jobs=120_000, seed=3)
        assert r.latency_p50[i] == pytest.approx(s.latency_p50, rel=0.06)
        assert r.latency_p99[i] == pytest.approx(s.latency_p99, rel=0.08)

    def test_energy_via_shared_schema(self, base_result):
        """η from the sweep equals Eq. 19 on its measured E[B], and the
        scalar simulator's η at the same point agrees."""
        from repro.core.energy import eta_given_EB
        _, r = base_result
        beta, c0 = 0.05, 0.2
        i = 2
        s = simulate(RHOS[i] / V100.alpha, V100, n_jobs=120_000, seed=5)
        eta_sweep = r.point(i).eta(beta, c0)
        assert eta_sweep == pytest.approx(
            float(eta_given_EB(r.mean_batch[i], beta, c0)), rel=1e-9)
        assert eta_sweep == pytest.approx(s.eta(beta, c0), rel=0.03)


class TestEvaluateEntryPoint:
    def test_backends_agree(self):
        grid = SweepGrid.from_rhos([0.3, 0.6], V100.alpha, V100.tau0)
        mk = evaluate(grid, backend="markov")
        sw = evaluate(grid, backend="sweep", n_batches=4000, seed=29)
        anl = evaluate(grid, backend="analytic")
        for m, s, a in zip(mk, sw, anl):
            assert s.mean_latency == pytest.approx(m.mean_latency,
                                                   rel=0.04)
            assert m.mean_latency <= a.mean_latency * (1 + 1e-9)
            assert {m.backend, s.backend, a.backend} == \
                {"markov", "sweep", "analytic"}

    def test_sim_backend_roundtrip(self):
        grid = SweepGrid.from_rhos([0.4], V100.alpha, V100.tau0)
        (s,) = evaluate(grid, backend="sim", n_jobs=60_000, seed=1)
        m = solve(0.4 / V100.alpha, V100)
        assert s.mean_latency == pytest.approx(m.mean_latency, rel=0.05)
        assert s.backend == "sim"

    def test_unsupported_points_raise(self):
        g_exp = SweepGrid.from_product([1.0], [V100.alpha], [V100.tau0],
                                       dists=("exp",))
        with pytest.raises(ValueError):
            evaluate(g_exp, backend="analytic")
        with pytest.raises(ValueError):
            evaluate(g_exp, backend="markov")
        g_to = SweepGrid.from_points([1.0], [V100.alpha], [V100.tau0],
                                     b_max=[8], wait_max=[1.0],
                                     wait_target=[4])
        with pytest.raises(ValueError):
            evaluate(g_to, backend="sim")
        with pytest.raises(ValueError):
            evaluate(g_to, backend="nope")


class TestGridConstruction:
    def test_product_and_points(self):
        g = SweepGrid.from_product([1.0, 2.0], [0.1], [1.0, 2.0],
                                   b_maxes=(0, 8))
        assert len(g) == 8
        g2 = SweepGrid.from_points([1.0, 2.0], 0.1, 1.0)
        assert len(g2) == 2 and np.all(g2.alpha == np.float32(0.1))
        assert len(g.concat(g2)) == 10

    def test_dist_codes(self):
        g = SweepGrid.from_product([1.0], [0.1], [1.0],
                                   dists=("det", "exp", "gamma"))
        assert set(g.dist.tolist()) == set(DIST_CODE.values())

    def test_validation_errors(self):
        g = SweepGrid.from_points([1.0], [0.1], [1.0], b_max=[4096])
        with pytest.raises(ValueError):
            sweep(g, q_cap=512)
        g2 = SweepGrid.from_rhos([0.5], 0.1, 1.0)
        with pytest.raises(ValueError):
            sweep(g2, n_batches=100, warmup=100)
        with pytest.raises(ValueError):
            sweep(g2, a_cap=1024, q_cap=512)
