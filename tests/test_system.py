"""End-to-end behaviour of the full reproduction: the paper's pipeline from
calibration → closed-form prediction → planning, run against a real model."""
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced
from repro.core import (LinearServiceModel, Planner, fit_service_model, phi,
                        simulate, solve_markov)
from repro.serving import InferenceEngine


def test_all_ten_architectures_registered():
    archs = list_archs()
    assert len(archs) == 10
    families = {get_config(a).family for a in archs}
    assert families == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}


def test_paper_pipeline_end_to_end():
    """The full loop the paper enables:
    1. measure τ^[b] on a real (reduced) model,
    2. fit (α, τ0) — Assumption 4,
    3. predict the latency curve via φ — Theorem 2,
    4. verify against the exact queueing model at those constants,
    5. plan an SLO-compliant operating point."""
    cfg = reduced(get_config("qwen1.5-0.5b"))
    eng = InferenceEngine(cfg, workload="forward", seq_len=32, max_batch=16)
    b, t = eng.calibrate(samples=5)
    model, r2 = fit_service_model(b, t)
    # CPU wall-clock jitter bounds the achievable fit in CI; the precise
    # R² (0.95+ unloaded) is reported by benchmarks/fig9_batch_times.py
    assert r2 > 0.6

    lam = 0.5 / model.alpha
    bound = float(phi(lam, model.alpha, model.tau0))
    exact = solve_markov(lam, model).mean_latency
    assert exact <= bound * (1 + 1e-9)
    assert exact >= 0.5 * bound

    planner = Planner(model)
    lam_max = planner.max_rate_for_slo(2 * bound)
    assert lam_max > lam         # looser SLO admits more load


def test_simulation_matches_served_reality_in_shape():
    """The simulator with the engine's fitted constants reproduces the
    engine's qualitative behaviour (monotone E[W], E[B] growth)."""
    cfg = reduced(get_config("qwen1.5-0.5b"))
    eng = InferenceEngine(cfg, workload="forward", seq_len=32, max_batch=8)
    model, _ = eng.fit_service_model(samples=3)
    lams = [0.15 / model.alpha, 0.5 / model.alpha]
    served = [eng.serve_poisson(l, n_jobs=120, seed=0) for l in lams]
    simmed = [simulate(l, model, n_jobs=50_000, b_max=8, seed=0)
              for l in lams]
    assert served[1].mean_batch > served[0].mean_batch
    assert simmed[1].mean_batch > simmed[0].mean_batch
    assert served[1].mean_latency > served[0].mean_latency * 0.8
    assert simmed[1].mean_latency > simmed[0].mean_latency
