"""Training substrate tests: optimizer math, data pipeline, checkpointing,
multi-step convergence."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import transformer as tfm
from repro.train import AdamWConfig, init_state, make_train_step, train
from repro.train.checkpoint import restore, save
from repro.train.data import DataConfig, SyntheticCorpus
from repro.train.optimizer import apply_updates, global_norm, schedule


class TestOptimizer:
    def test_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
        lrs = [float(schedule(cfg, jnp.asarray(s))) for s in range(100)]
        assert lrs[0] < lrs[9]                     # warmup
        assert max(lrs) <= 1.0 + 1e-6
        assert lrs[-1] == pytest.approx(0.1, abs=0.05)   # cosine floor

    def test_grad_clip(self):
        cfg = AdamWConfig(grad_clip=1.0, weight_decay=0.0)
        params = {"w": jnp.ones((4, 4))}
        grads = {"w": jnp.full((4, 4), 100.0)}
        st = init_state(params)
        newp, st2, gnorm = apply_updates(cfg, params, grads, st)
        assert float(gnorm) == pytest.approx(400.0)
        # post-clip effective step bounded by lr
        assert float(jnp.max(jnp.abs(newp["w"] - params["w"]))) < 2 * cfg.lr

    def test_quadratic_convergence(self):
        """AdamW minimizes a quadratic."""
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                          total_steps=200, grad_clip=0.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        st = init_state(params)
        for _ in range(200):
            g = {"w": 2 * params["w"]}
            params, st, _ = apply_updates(cfg, params, g, st)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.05

    def test_moments_are_f32(self):
        params = {"w": jnp.ones((2, 2), jnp.bfloat16)}
        st = init_state(params)
        assert st.mu["w"].dtype == jnp.float32
        assert st.nu["w"].dtype == jnp.float32


class TestData:
    def test_deterministic_and_learnable(self):
        cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4, seed=1)
        a = next(SyntheticCorpus(cfg).batches())
        b = next(SyntheticCorpus(cfg).batches())
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        assert a["tokens"].shape == (4, 16)
        assert a["labels"].shape == (4, 16)
        assert a["tokens"].max() < 128
        # labels are input shifted by one
        np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


class TestCheckpoint:
    def test_roundtrip(self):
        cfg = reduced(get_config("qwen1.5-0.5b"))
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "ckpt.npz")
            save(path, params)
            zeros = jax.tree.map(jnp.zeros_like, params)
            back = restore(path, zeros)
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestEndToEnd:
    def test_loss_decreases_100_steps(self):
        cfg = reduced(get_config("qwen1.5-0.5b"))
        r = train(cfg, steps=40, global_batch=8, seq_len=32, log_every=0)
        assert r.last_loss < r.first_loss - 0.2
        assert np.isfinite(r.losses).all()

    def test_moe_aux_loss_active(self):
        cfg = reduced(get_config("olmoe-1b-7b"))
        key = jax.random.PRNGKey(0)
        params = tfm.init_params(cfg, key)
        batch = {
            "tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (2, 32), 0, cfg.vocab_size),
        }
        step = jax.jit(make_train_step(cfg, AdamWConfig(total_steps=10)))
        _, _, m = step(params, init_state(params), batch)
        assert float(m["aux"]) > 0.5     # load-balance loss near E·(1/E)·1≈1

    def test_remat_matches_no_remat(self):
        cfg = reduced(get_config("qwen1.5-0.5b"))
        key = jax.random.PRNGKey(0)
        params = tfm.init_params(cfg, key)
        batch = {
            "tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (2, 32), 0, cfg.vocab_size),
        }
        from repro.train.loop import loss_fn
        l1, _ = loss_fn(cfg, params, batch, remat=False)
        l2, _ = loss_fn(cfg, params, batch, remat=True)
        assert float(l1) == pytest.approx(float(l2), rel=1e-6)
        g1 = jax.grad(lambda p: loss_fn(cfg, p, batch, remat=False)[0])(
            params)
        g2 = jax.grad(lambda p: loss_fn(cfg, p, batch, remat=True)[0])(
            params)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)


class TestChunkedCrossEntropy:
    def test_matches_plain_value_and_grads(self):
        """§Perf P1 path is numerically identical to the plain loss."""
        import repro.train.loop as loop
        cfg = reduced(get_config("qwen1.5-0.5b"))
        key = jax.random.PRNGKey(5)
        params = tfm.init_params(cfg, key)
        b, s = 2, 64
        batch = {
            "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        }
        l_plain, _ = loop.loss_fn(cfg, params, batch)
        # force the chunked path
        old_chunk, old_thresh = loop.CE_CHUNK, loop.CE_CHUNK_THRESHOLD
        loop.CE_CHUNK, loop.CE_CHUNK_THRESHOLD = 16, 0
        try:
            l_chunk, _ = loop.loss_fn(cfg, params, batch)
            g_plain = jax.grad(
                lambda p: loop.loss_fn(cfg, p, batch)[0])(params)
        finally:
            loop.CE_CHUNK, loop.CE_CHUNK_THRESHOLD = old_chunk, old_thresh
        g_ref = jax.grad(lambda p: loop.loss_fn(cfg, p, batch)[0])(params)
        assert float(l_plain) == pytest.approx(float(l_chunk), rel=1e-6)
        for a, b_ in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_plain)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=2e-4, atol=2e-5)


class TestMicrobatching:
    def test_matches_single_batch(self):
        """Gradient accumulation gives the same update (up to fp
        reassociation) as the single-shot step."""
        cfg = reduced(get_config("qwen1.5-0.5b"))
        key = jax.random.PRNGKey(9)
        params = tfm.init_params(cfg, key)
        batch = {
            "tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
        }
        opt = AdamWConfig(total_steps=10, warmup_steps=1)
        p1, _, m1 = jax.jit(make_train_step(cfg, opt))(
            params, init_state(params), batch)
        p2, _, m2 = jax.jit(make_train_step(cfg, opt, microbatches=4))(
            params, init_state(params), batch)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]),
                                                  rel=1e-5)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=5e-3, atol=5e-4)
