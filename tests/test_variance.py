"""Adaptive-precision statistics (repro.core.variance): the host-side
batch-means/allocation/control-variate layer and its kernel contracts.

Three layers of evidence:

- pure-numpy unit tests for every formula (batch-means stderr, pow2
  cycle allocation, β̂ clipping, CV adjustment, paired differencing);
- the CRN key contracts the docstrings promise: a det-service grid IS
  its own companion (bitwise — same fold_in keys, same dispatch), and
  the paired A−B sd across a seed ladder respects the conservative
  √(s_a²+s_b²) bound;
- statistical coverage: the nominal-95% regenerative CIs shipped by
  the sweep and gen kernels must cover the exact truncated-chain mean
  on a seed ladder.  Batch means over finitely many blocks slightly
  underestimates the variance of a correlated sequence, so the
  acceptance band is tolerance-banded below 0.95 (empirically ~0.87 ±
  0.06 at 30 seeds for both kernels at these operating points — see
  docs/theory.md §"Adaptive precision"); a band violation means the
  carry accumulators, not the tolerance, broke.
"""
import math
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import variance
from repro.core.analytic import LinearServiceModel
from repro.core.continuous_sim import GenServiceModel
from repro.core.gen_sweep import gen_sweep
from repro.core.grid import FleetGrid, GenGrid, SweepGrid
from repro.core.markov import solve
from repro.core.sweep import fleet_sweep, sweep
from repro.core.variance import (Z95, allocate_cycles, batch_means_stats,
                                 cv_adjust, crn_pair_diff, estimate_beta)

V100 = LinearServiceModel(alpha=0.1438, tau0=1.8874)


# ---------------------------------------------------------------------------
# pure formula layer
# ---------------------------------------------------------------------------
class TestBatchMeans:
    def test_matches_manual_welford(self):
        rng = np.random.default_rng(0)
        x = rng.normal(size=17)
        m2 = ((x - x.mean()) ** 2).sum()
        se, hw = batch_means_stats(m2, len(x))
        want = math.sqrt(x.var(ddof=1) / len(x))
        assert se == pytest.approx(want, rel=1e-12)
        assert hw == pytest.approx(Z95 * want, rel=1e-12)

    def test_fewer_than_two_blocks_is_nan(self):
        se, hw = batch_means_stats([0.0, 0.5, 3.0], [0, 1, 2])
        assert np.isnan(se[:2]).all() and np.isnan(hw[:2]).all()
        assert np.isfinite(se[2]) and hw[2] == pytest.approx(Z95 * se[2])

    def test_zero_m2_gives_zero_stderr(self):
        se, hw = batch_means_stats(0.0, 8)
        assert se == 0.0 and hw == 0.0


class TestAllocateCycles:
    def test_target_mode_pow2_quantized_and_capped(self):
        # ci/target = 2 ⇒ factor 4 ⇒ exactly pilot·4 (no overshoot);
        # ci/target = 2.1 ⇒ factor 4.41 ⇒ next tier pilot·8; a huge
        # ratio hits the n_max ceiling
        alloc = allocate_cycles([2.0, 2.1, 100.0], 128, n_max=2048,
                                target_ci=1.0)
        assert alloc.tolist() == [512, 1024, 2048]

    def test_converged_and_nan_points_stay_at_pilot(self):
        alloc = allocate_cycles([0.5, np.nan, 0.0], 128, n_max=2048,
                                target_ci=1.0)
        assert alloc.tolist() == [128, 128, 128]

    def test_safety_pads_the_factor(self):
        base = allocate_cycles([1.0], 128, n_max=4096, target_ci=1.0)
        padded = allocate_cycles([1.0], 128, n_max=4096, target_ci=1.0,
                                 safety=4.0)
        assert base.tolist() == [128] and padded.tolist() == [512]

    def test_neyman_allocates_proportionally(self):
        alloc = allocate_cycles([1.0, 3.0, np.nan], 100, n_max=10_000,
                                refine_budget=400)
        # extra = 400·[1,3,0]/4 = [100, 300] ⇒ factors [2, 4]
        assert alloc.tolist() == [200, 400, 100]

    def test_exactly_one_policy_required(self):
        with pytest.raises(ValueError, match="exactly one"):
            allocate_cycles([1.0], 10, n_max=100)
        with pytest.raises(ValueError, match="exactly one"):
            allocate_cycles([1.0], 10, n_max=100, target_ci=1.0,
                            refine_budget=5)

    def test_bounds_validation(self):
        with pytest.raises(ValueError, match="pilot"):
            allocate_cycles([1.0], 0, n_max=100, target_ci=1.0)
        with pytest.raises(ValueError, match="pilot"):
            allocate_cycles([1.0], 200, n_max=100, target_ci=1.0)
        with pytest.raises(ValueError, match="target_ci"):
            allocate_cycles([1.0], 10, n_max=100, target_ci=0.0)


class TestControlVariateFormulas:
    def test_beta_is_clipped_stderr_ratio(self):
        beta = estimate_beta([2.0, 9.0, 1.0, np.nan], [1.0, 2.0, 0.0, 1.0])
        assert beta[0] == 2.0          # ratio
        assert beta[1] == 2.0          # clipped at default 2
        assert beta[2] == 1.0          # sc == 0 ⇒ fallback
        assert beta[3] == 1.0          # NaN ⇒ fallback

    def test_cv_adjust_default_beta_one(self):
        out = cv_adjust([10.0, 10.0], [4.0, 3.0], [3.0, 3.0])
        assert out.tolist() == [9.0, 10.0]

    def test_cv_adjust_vector_beta(self):
        out = cv_adjust(10.0, 4.0, 3.0, beta=[0.5, 2.0])
        assert out.tolist() == [9.5, 8.0]

    def test_pair_diff_formula_and_shape_guard(self):
        a = SimpleNamespace(mean_latency=np.array([3.0, 5.0]),
                            stderr=np.array([0.3, 0.4]))
        b = SimpleNamespace(mean_latency=np.array([2.0, 1.0]),
                            stderr=np.array([0.4, 0.3]))
        d = crn_pair_diff(a, b)
        assert d["diff"].tolist() == [1.0, 4.0]
        assert d["stderr"] == pytest.approx([0.5, 0.5])
        assert d["halfwidth"] == pytest.approx([Z95 * 0.5] * 2)
        short = SimpleNamespace(mean_latency=np.array([1.0]),
                                stderr=np.array([0.1]))
        with pytest.raises(ValueError, match="equal point counts"):
            crn_pair_diff(a, short)


# ---------------------------------------------------------------------------
# CRN key contracts against the kernels
# ---------------------------------------------------------------------------
class TestCompanionContracts:
    def test_det_grid_is_its_own_companion_bitwise(self):
        # companion_grid only rewrites the dist axis; for an already-
        # deterministic grid the companion dispatch must be THE SAME
        # dispatch — same fold_in keys, bitwise-equal results.  This
        # pins the key contract cv_adjust's CRN coupling relies on.
        g = SweepGrid.from_points([2.0, 3.0], V100.alpha, V100.tau0,
                                  b_max=8, dist="det")
        comp = variance.companion_grid(g)
        assert np.array_equal(np.asarray(comp.dist), np.asarray(g.dist))
        a = sweep(g, n_batches=256, seed=5)
        b = sweep(comp, n_batches=256, seed=5)
        assert np.array_equal(a.mean_latency, b.mean_latency)
        assert np.array_equal(a.ci_halfwidth, b.ci_halfwidth,
                              equal_nan=True)
        # with a perfectly coupled companion and β = 1, the adjusted
        # estimate collapses onto the reference exactly
        ref, exact = variance.companion_reference(comp)
        assert exact.all()
        adj = cv_adjust(a.mean_latency, b.mean_latency, ref)
        assert adj == pytest.approx(ref)

    def test_companion_reference_chain_vs_phi(self):
        from repro.core.analytic import phi

        g = SweepGrid.from_points([1.0, 2.5], V100.alpha, V100.tau0,
                                  b_max=[4, 0], dist="det")
        ref, exact = variance.companion_reference(g)
        assert exact.tolist() == [True, False]
        assert ref[0] == pytest.approx(
            solve(1.0, V100, b_max=4).mean_latency)
        assert ref[1] == pytest.approx(phi(2.5, V100.alpha, V100.tau0))

    def test_paired_sd_within_conservative_bound(self):
        # jsq-vs-random at shared seeds: the empirical sd of the paired
        # difference across a seed ladder must respect the conservative
        # √(s_a²+s_b²) bound crn_pair_diff reports (positively coupled
        # arms can only shrink the true sd).  1.3 covers the χ² noise
        # of a 6-seed sd estimate.
        lams = [rho / V100.alpha for rho in (0.3, 0.5, 0.7)]
        kw = dict(ks=[4])
        gj = FleetGrid.from_product(lams, [V100.alpha], [V100.tau0],
                                    routings=("jsq",), **kw)
        gr = FleetGrid.from_product(lams, [V100.alpha], [V100.tau0],
                                    routings=("random",), **kw)
        paired, bounds = [], []
        for s in range(6):
            a = fleet_sweep(gj, n_steps=2000, a_cap=32, hist_every=4,
                            seed=s)
            b = fleet_sweep(gr, n_steps=2000, a_cap=32, hist_every=4,
                            seed=s)
            d = crn_pair_diff(a, b)
            paired.append(d["diff"])
            bounds.append(d["stderr"])
        sd = np.asarray(paired).std(axis=0, ddof=1)
        bound = np.mean(bounds, axis=0)
        assert sd.sum() <= 1.3 * bound.sum()


# ---------------------------------------------------------------------------
# statistical coverage of the shipped CIs
# ---------------------------------------------------------------------------
class TestCoverage:
    def test_sweep_ci_covers_exact_chain_mean(self):
        lam = 0.5 * 4 / (V100.alpha * 4 + V100.tau0)
        exact = solve(lam, V100, b_max=4).mean_latency
        g = SweepGrid.from_points(lam, V100.alpha, V100.tau0, b_max=4,
                                  dist="det")
        hits, errs = 0, []
        for s in range(30):
            r = sweep(g, n_batches=2048, seed=s)
            m, hw = float(r.mean_latency[0]), float(r.ci_halfwidth[0])
            assert hw > 0
            assert float(r.stderr[0]) == pytest.approx(hw / Z95)
            hits += abs(m - exact) <= hw
            errs.append(m - exact)
        assert hits / 30 >= 0.75          # empirically 0.90
        # the ladder mean is unbiased well beyond the per-seed CI
        assert abs(np.mean(errs)) <= exact * 0.01

    def test_gen_ci_covers_equivalent_law_chain_mean(self):
        model = GenServiceModel(alpha_decode=0.14, tau0_decode=1.9,
                                alpha_prefill=0.035, tau0_prefill=1.9)
        gen_tok, prompt, cap = 32, 128, 64
        alpha_eq = prompt * model.alpha_prefill + gen_tok * model.alpha_decode
        tau0_eq = model.tau0_prefill + gen_tok * model.tau0_decode
        lam = 0.5 / alpha_eq
        exact = solve(lam, LinearServiceModel(alpha_eq, tau0_eq),
                      b_max=cap).mean_latency
        g = GenGrid.from_points(
            lam, model.alpha_decode, model.tau0_decode,
            model.alpha_prefill, model.tau0_prefill, prompt_len=prompt,
            gen_tokens=gen_tok, max_active=cap, discipline="static")
        hits = 0
        for s in range(30):
            r = gen_sweep(g, n_steps=8192, q_cap=256, a_cap=64, seed=s)
            assert float(r.ci_halfwidth[0]) > 0
            hits += (abs(float(r.mean_latency[0]) - exact)
                     <= float(r.ci_halfwidth[0]))
        assert hits / 30 >= 0.70          # empirically 0.87
